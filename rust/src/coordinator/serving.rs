//! Event-driven serving tier on top of the supervised lane pool.
//!
//! Batch entry points block the submitting thread until the whole batch
//! drains; a server cannot afford that. [`ServingPool`] decouples
//! request ingest from accelerator occupancy: [`ServingPool::submit`]
//! and [`ClientStream::try_submit`] return immediately with a
//! [`CompletionHandle`], and the handle is fulfilled by a hand-rolled
//! waker-style completion event the moment the dispatcher's done
//! channel emits the job's outcome (see
//! [`run_supervised_lane_pool_tapped`]) — no tokio, the crate stays
//! `anyhow`-only.
//!
//! Backpressure never blocks a lane: every client stream carries a
//! bounded in-flight gate, and a submission that finds the stream (or
//! the pool) full is either **parked** — the job is handed back for the
//! caller to retry — or **shed** with a structured
//! [`StopReason::Shed`] outcome, depending on its [`SloClass`].
//! Latency-critical work is never queued into a future it cannot meet:
//! when the estimated queue wait already exceeds the job's deadline
//! budget, the pool resolves the handle immediately instead of letting
//! the job expire in a queue.

use super::completion::CompletionCell;
use super::jobs::{LaneIcpConfig, LaneReport, RegistrationJob, RegistrationOutcome, SloClass};
use super::supervise::{run_supervised_lane_pool_tapped, SupervisorConfig};
use crate::fpps_api::KernelBackend;
use crate::icp::StopReason;
use crate::math::Mat4;
use crate::metrics::TimingStats;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Admission policy of the serving tier (how much work may be in
/// flight, per client stream and pool-wide) — distinct from the
/// residency-footprint [`AdmissionPolicy`](super::AdmissionPolicy),
/// which guards device memory rather than queueing.
#[derive(Clone, Copy, Debug)]
pub struct ServingConfig {
    /// Per-[`ClientStream`] in-flight bound: a stream at its depth
    /// parks (or sheds, for latency-critical work) further submissions.
    /// `0` admits nothing through that stream — useful to drain.
    pub stream_depth: usize,
    /// Pool-wide in-flight bound across all streams; the backstop that
    /// keeps aggregate queueing (and thus queue wait) bounded no matter
    /// how many streams exist. `0` admits nothing.
    pub max_in_flight: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            stream_depth: 4,
            max_in_flight: 1024,
        }
    }
}

/// What happened to a [`ClientStream::try_submit`] call. Accepting and
/// shedding both yield a [`CompletionHandle`] (a shed handle is already
/// complete, carrying the structured [`StopReason::Shed`] outcome);
/// parking hands the job back untouched so the caller can retry —
/// [`RegistrationJob`] is deliberately not `Clone`, the points never
/// get copied on the admission path.
pub enum Submission {
    /// Queued; the handle completes when a lane (or the watchdog)
    /// resolves the job.
    Accepted(CompletionHandle),
    /// Refused by admission; the handle is already complete with a
    /// [`StopReason::Shed`] outcome explaining why.
    Shed(CompletionHandle),
    /// Stream or pool full and the job's class queues rather than
    /// sheds: the job is handed back, retry when capacity frees up.
    Parked(RegistrationJob),
}

/// The serving tier's one-shot completion cell — the generic waker
/// state machine lives in [`super::completion`] (model-checked under
/// `--cfg loom`); serving pins it to [`RegistrationOutcome`].
type Completion = CompletionCell<RegistrationOutcome>;

/// Handle to one submitted job's eventual [`RegistrationOutcome`].
///
/// Completion is edge-triggered and hand-rolled: the pool's outcome tap
/// fulfills the handle the moment the job resolves, waking any
/// [`Self::wait`]er and firing the [`Self::set_waker`] callback. The
/// outcome itself is moved out exactly once — by whichever of
/// [`Self::try_take`] / [`Self::wait`] / [`Self::wait_timeout`] gets
/// there first.
pub struct CompletionHandle {
    id: u64,
    class: SloClass,
    inner: Arc<Completion>,
}

impl CompletionHandle {
    /// Id of the job this handle tracks.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// SLO class the job was submitted under.
    pub fn class(&self) -> SloClass {
        self.class
    }

    /// Has the job resolved (even if its outcome was already taken)?
    pub fn is_complete(&self) -> bool {
        self.inner.is_complete()
    }

    /// Non-blocking: the outcome if the job has resolved and nobody
    /// took it yet.
    pub fn try_take(&self) -> Option<RegistrationOutcome> {
        self.inner.try_take()
    }

    /// Block until the job resolves.
    ///
    /// # Panics
    /// If the outcome was already consumed by [`Self::try_take`] /
    /// [`Self::wait_timeout`].
    pub fn wait(self) -> RegistrationOutcome {
        self.inner.wait()
    }

    /// Block until the job resolves or `timeout` elapses; `None` on
    /// timeout (or when the outcome was already taken).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<RegistrationOutcome> {
        self.inner.wait_timeout(timeout)
    }

    /// Register a callback fired exactly once when the job resolves —
    /// immediately (on the caller's thread) if it already has, else on
    /// the pool's collector thread. The last registration wins; an
    /// earlier unfired waker is dropped. Wakers must not block: they
    /// run on the thread that fulfills every handle in the pool.
    pub fn set_waker(&self, waker: impl FnOnce() + Send + 'static) {
        self.inner.set_waker(waker)
    }
}

/// Per-stream in-flight counter (the stream's backpressure gate).
struct StreamGate {
    in_flight: AtomicUsize,
}

/// Registry entry for an accepted-but-unresolved job.
struct Pending {
    completion: Arc<Completion>,
    gate: Arc<StreamGate>,
    class: SloClass,
    stream: usize,
    initial: Mat4,
    submitted: Instant,
}

/// Per-class serving accumulators (guarded by one mutex in [`Shared`]).
#[derive(Default)]
struct ClassAccum {
    submitted: usize,
    completed: usize,
    ok: usize,
    failed: usize,
    shed: usize,
    latency: TimingStats,
}

fn class_index(class: SloClass) -> usize {
    match class {
        SloClass::LatencyCritical => 0,
        SloClass::Standard => 1,
        SloClass::BestEffort => 2,
    }
}

/// State shared between the submitting threads and the pool's outcome
/// tap.
struct Shared {
    pending: Mutex<HashMap<u64, Pending>>,
    in_flight: AtomicUsize,
    closed: AtomicBool,
    classes: Mutex<[ClassAccum; 3]>,
    /// EMA of observed service time, feeding the queue-wait estimate
    /// behind latency-critical deadline shedding. 0.0 until the first
    /// outcome lands.
    ema_service_ms: Mutex<f64>,
}

impl Shared {
    fn new() -> Self {
        Shared {
            pending: Mutex::new(HashMap::new()),
            in_flight: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            classes: Mutex::new(Default::default()),
            ema_service_ms: Mutex::new(0.0),
        }
    }

    /// The pool's outcome tap: resolve the job's handle, release its
    /// gates, and fold the completion into the per-class stats. Runs on
    /// the pool's collector thread, once per outcome.
    fn fulfill(&self, outcome: &RegistrationOutcome) {
        let entry = self.pending.lock().unwrap().remove(&outcome.id);
        let Some(p) = entry else {
            return; // not a serving submission (defensive; cannot happen)
        };
        // ordering: AcqRel — gate decrements pair with the AcqRel
        // increments in `try_submit`, so a submitter that observes a
        // freed slot also observes the completed job's registry removal.
        p.gate.in_flight.fetch_sub(1, Ordering::AcqRel);
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
        let latency_ms = p.submitted.elapsed().as_secs_f64() * 1e3;
        {
            let mut classes = self.classes.lock().unwrap();
            let acc = &mut classes[class_index(p.class)];
            acc.completed += 1;
            if outcome.is_failed() {
                acc.failed += 1;
            } else {
                acc.ok += 1;
            }
            acc.latency.record_ms(latency_ms);
        }
        {
            let mut ema = self.ema_service_ms.lock().unwrap();
            *ema = if *ema == 0.0 {
                outcome.service_ms
            } else {
                0.8 * *ema + 0.2 * outcome.service_ms
            };
        }
        p.completion.complete(outcome.clone());
    }

    fn account_shed(&self, class: SloClass) {
        let mut classes = self.classes.lock().unwrap();
        let acc = &mut classes[class_index(class)];
        acc.submitted += 1;
        acc.shed += 1;
    }
}

/// The structured outcome of a shed: the job never reached a lane, the
/// initial transform is handed back, and `lane` is `usize::MAX`
/// (deliberately meaningless — no lane ever saw the job).
fn shed_outcome(id: u64, stream: usize, initial: Mat4, reason: &str) -> RegistrationOutcome {
    RegistrationOutcome {
        id,
        stream,
        lane: usize::MAX,
        transform: initial,
        rmse: f64::NAN,
        iterations: 0,
        stop: StopReason::Shed,
        queue_wait_ms: 0.0,
        service_ms: 0.0,
        error: Some(format!("job {id} shed before queueing: {reason}")),
        attempts: 0,
    }
}

enum IntakeMsg {
    Job(RegistrationJob),
    Shutdown,
}

/// Per-client submission endpoint with its own bounded in-flight gate.
/// Cheap to create (two `Arc`s); make one per simulated client. All
/// admission decisions — gate checks, SLO shedding, the deadline-doom
/// estimate — happen on the submitting thread, so a full stream can
/// never block a lane.
pub struct ClientStream {
    shared: Arc<Shared>,
    intake: Sender<IntakeMsg>,
    gate: Arc<StreamGate>,
    stream_depth: usize,
    max_in_flight: usize,
    lanes: usize,
    sup_deadline: Option<Duration>,
}

impl ClientStream {
    /// Non-blocking submission. Returns [`Submission::Accepted`] with a
    /// live handle, [`Submission::Shed`] with an already-resolved
    /// handle (latency-critical jobs refused by admission), or
    /// [`Submission::Parked`] handing the job back (standard /
    /// best-effort jobs under backpressure).
    ///
    /// Job ids must be unique among in-flight submissions — they key
    /// the completion registry; a duplicate is an error.
    pub fn try_submit(&self, mut job: RegistrationJob) -> Result<Submission> {
        // ordering: Acquire — pairs with the Release close in `shutdown`
        // so a submitter that sees `closed` also sees the drained state.
        if self.shared.closed.load(Ordering::Acquire) {
            bail!("serving pool is shut down");
        }
        let class = job.slo;
        // ordering: Acquire — pairs with the AcqRel decrements in
        // `fulfill`; admission must observe completed jobs' releases.
        if self.gate.in_flight.load(Ordering::Acquire) >= self.stream_depth {
            return Ok(self.refuse(job, "stream at its in-flight depth"));
        }
        // ordering: Acquire — pool-wide bound, same pairing as above.
        if self.shared.in_flight.load(Ordering::Acquire) >= self.max_in_flight {
            return Ok(self.refuse(job, "pool at its in-flight bound"));
        }
        if class == SloClass::LatencyCritical {
            if let Some(budget) = job.deadline.or(self.sup_deadline) {
                // ordering: Acquire — consistent view for the queue-wait
                // estimate (an advisory heuristic, not a hard bound).
                let in_flight = self.shared.in_flight.load(Ordering::Acquire);
                let ema = *self.shared.ema_service_ms.lock().unwrap();
                let est_wait_ms = in_flight as f64 / self.lanes as f64 * ema;
                if budget.as_secs_f64() * 1e3 <= est_wait_ms {
                    return Ok(self.shed(job, "estimated queue wait exceeds deadline budget"));
                }
            }
        }
        let completion = Arc::new(Completion::new());
        {
            let mut pending = self.shared.pending.lock().unwrap();
            match pending.entry(job.id) {
                std::collections::hash_map::Entry::Occupied(_) => {
                    bail!("job id {} is already in flight", job.id)
                }
                std::collections::hash_map::Entry::Vacant(slot) => {
                    slot.insert(Pending {
                        completion: Arc::clone(&completion),
                        gate: Arc::clone(&self.gate),
                        class,
                        stream: job.stream,
                        initial: job.initial,
                        submitted: Instant::now(),
                    });
                }
            }
        }
        // ordering: AcqRel — pairs with the admission loads and the
        // `fulfill` decrements (see the comments above).
        self.gate.in_flight.fetch_add(1, Ordering::AcqRel);
        self.shared.in_flight.fetch_add(1, Ordering::AcqRel);
        self.shared.classes.lock().unwrap()[class_index(class)].submitted += 1;
        job.mark_submitted(); // queue wait starts now, not at job build
        let id = job.id;
        if self.intake.send(IntakeMsg::Job(job)).is_err() {
            // Pool shut down between the closed check and the send:
            // undo the registration and report the truth.
            if let Some(p) = self.shared.pending.lock().unwrap().remove(&id) {
                // ordering: AcqRel — undo of the increments above.
                p.gate.in_flight.fetch_sub(1, Ordering::AcqRel);
                self.shared.in_flight.fetch_sub(1, Ordering::AcqRel);
            }
            bail!("serving pool is shut down");
        }
        Ok(Submission::Accepted(CompletionHandle {
            id,
            class,
            inner: completion,
        }))
    }

    /// Jobs currently in flight through this stream.
    pub fn in_flight(&self) -> usize {
        // ordering: Acquire — pairs with the `fulfill` decrements.
        self.gate.in_flight.load(Ordering::Acquire)
    }

    /// Backpressure refusal: shed latency-critical work (it must not
    /// queue), park everything else.
    fn refuse(&self, job: RegistrationJob, reason: &str) -> Submission {
        if job.slo == SloClass::LatencyCritical {
            self.shed(job, reason)
        } else {
            Submission::Parked(job)
        }
    }

    fn shed(&self, job: RegistrationJob, reason: &str) -> Submission {
        self.shared.account_shed(job.slo);
        let completion = Arc::new(Completion::new());
        completion.complete(shed_outcome(job.id, job.stream, job.initial, reason));
        Submission::Shed(CompletionHandle {
            id: job.id,
            class: job.slo,
            inner: completion,
        })
    }
}

/// Per-class serving statistics, reported by [`ServingPool::shutdown`].
#[derive(Clone, Debug)]
pub struct SloClassStats {
    pub class: SloClass,
    /// Submissions admitted or shed under this class (parks excluded —
    /// a parked job was never accepted).
    pub submitted: usize,
    /// Jobs that reached a lane and resolved.
    pub completed: usize,
    /// Completed without a contained error.
    pub ok: usize,
    /// Completed with a contained error (align failure or deadline);
    /// included in `completed`.
    pub failed: usize,
    /// Refused by admission with a structured [`StopReason::Shed`]
    /// outcome; included in `submitted`, never in `completed`.
    pub shed: usize,
    /// Submit-to-completion latency of completed jobs (queue wait +
    /// service + completion plumbing).
    pub latency: TimingStats,
}

/// Everything a serving run produced: the pool's [`LaneReport`] plus
/// the per-SLO-class serving view.
pub struct ServingReport {
    pub lane_report: LaneReport,
    /// One entry per [`SloClass`], in [`SloClass::all`] order.
    pub classes: Vec<SloClassStats>,
}

impl ServingReport {
    /// Render the per-class latency/shedding breakdown (p50/p99/p999 —
    /// the numbers the load generator and `fpps serve` print).
    pub fn class_table(&self) -> crate::report::Table {
        let mut t = crate::report::Table::new("serving classes").header(&[
            "class",
            "submitted",
            "completed",
            "ok",
            "fail",
            "shed",
            "p50 (ms)",
            "p99 (ms)",
            "p999 (ms)",
        ]);
        for c in &self.classes {
            t.row(vec![
                c.class.to_string(),
                c.submitted.to_string(),
                c.completed.to_string(),
                c.ok.to_string(),
                c.failed.to_string(),
                c.shed.to_string(),
                format!("{:.2}", c.latency.percentile_ms(50.0)),
                format!("{:.2}", c.latency.percentile_ms(99.0)),
                format!("{:.2}", c.latency.percentile_ms(99.9)),
            ]);
        }
        t
    }

    /// Total sheds across all classes.
    pub fn total_shed(&self) -> usize {
        self.classes.iter().map(|c| c.shed).sum()
    }

    /// Contained failures that were *not* deliberate sheds — the error
    /// count an exit gate should look at (outcome-derived, so it can
    /// never diverge from the printed failure list).
    pub fn contained_failures(&self) -> usize {
        self.lane_report
            .outcomes
            .iter()
            .filter(|o| o.is_failed() && o.stop != StopReason::Shed)
            .count()
    }
}

/// Non-blocking serving front-end over the supervised lane pool.
///
/// [`Self::start`] spawns the pool on a background thread; submissions
/// go through [`Self::submit`] (accept-or-shed, never blocks) or
/// per-client [`ClientStream`]s ([`Self::client`]) with bounded
/// backpressure. [`Self::shutdown`] stops intake, drains the pool, and
/// returns the [`ServingReport`].
///
/// Serving cannot change numerics: a job accepted here runs through
/// exactly the same lane-pool path as a batch submission, so Ok
/// outcomes stay bit-identical to the sequential engine (asserted by
/// `tests/serving.rs` and the `lane_engine` identity test).
pub struct ServingPool {
    shared: Arc<Shared>,
    intake: Sender<IntakeMsg>,
    handle: std::thread::JoinHandle<Result<LaneReport>>,
    stream_depth: usize,
    max_in_flight: usize,
    lanes: usize,
    sup_deadline: Option<Duration>,
}

impl ServingPool {
    /// Start the pool: `lanes` supervised worker lanes (see
    /// [`run_supervised_lane_pool_tapped`]) behind an unbounded intake
    /// stage, so admission happens in [`ClientStream::try_submit`]
    /// (shed/park) rather than by blocking the submitter on a bounded
    /// queue. `make_backend` follows the lane-pool factory contract
    /// (called on the lane thread, tier-aware).
    pub fn start<B, F>(
        lanes: usize,
        queue_depth: usize,
        icp_cfg: LaneIcpConfig,
        sup: SupervisorConfig,
        cfg: ServingConfig,
        make_backend: F,
    ) -> Result<ServingPool>
    where
        B: KernelBackend + 'static,
        F: Fn(usize, usize) -> Result<B> + Send + Sync + 'static,
    {
        let (intake, intake_rx) = channel::<IntakeMsg>();
        let shared = Arc::new(Shared::new());
        let tap_shared = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("fpps-serving".into())
            .spawn(move || {
                run_supervised_lane_pool_tapped(
                    lanes,
                    queue_depth,
                    icp_cfg,
                    sup,
                    make_backend,
                    move |tx| {
                        // Forwarder: the only place that may block on the
                        // pool's bounded queue — never a client thread.
                        for msg in intake_rx {
                            match msg {
                                IntakeMsg::Job(job) => {
                                    if tx.send(job).is_err() {
                                        break; // pool unwinding early
                                    }
                                }
                                IntakeMsg::Shutdown => break,
                            }
                        }
                        Ok(())
                    },
                    move |outcome| tap_shared.fulfill(outcome),
                )
            })
            .context("spawn serving pool thread")?;
        Ok(ServingPool {
            shared,
            intake,
            handle,
            stream_depth: cfg.stream_depth,
            max_in_flight: cfg.max_in_flight,
            lanes: lanes.max(1),
            sup_deadline: sup.deadline,
        })
    }

    /// A fresh per-client stream with its own bounded in-flight gate.
    pub fn client(&self) -> ClientStream {
        ClientStream {
            shared: Arc::clone(&self.shared),
            intake: self.intake.clone(),
            gate: Arc::new(StreamGate {
                in_flight: AtomicUsize::new(0),
            }),
            stream_depth: self.stream_depth,
            max_in_flight: self.max_in_flight,
            lanes: self.lanes,
            sup_deadline: self.sup_deadline,
        }
    }

    /// One-shot submission without a per-client stream: accepts or
    /// sheds, never parks and never blocks. (Backpressure that parks —
    /// so the caller can retry — is the [`ClientStream`] contract.)
    pub fn submit(&self, job: RegistrationJob) -> Result<CompletionHandle> {
        // A throwaway gate deep enough to never refuse: only the
        // pool-wide bound applies to the one-shot path.
        let stream = ClientStream {
            shared: Arc::clone(&self.shared),
            intake: self.intake.clone(),
            gate: Arc::new(StreamGate {
                in_flight: AtomicUsize::new(0),
            }),
            stream_depth: usize::MAX,
            max_in_flight: self.max_in_flight,
            lanes: self.lanes,
            sup_deadline: self.sup_deadline,
        };
        match stream.try_submit(job)? {
            Submission::Accepted(h) | Submission::Shed(h) => Ok(h),
            Submission::Parked(job) => {
                // Pool at capacity and the class parks: the one-shot
                // path has nowhere to park, so shed with structure.
                self.shared.account_shed(job.slo);
                let completion = Arc::new(Completion::new());
                completion.complete(shed_outcome(
                    job.id,
                    job.stream,
                    job.initial,
                    "pool at its in-flight bound",
                ));
                Ok(CompletionHandle {
                    id: job.id,
                    class: job.slo,
                    inner: completion,
                })
            }
        }
    }

    /// Jobs currently in flight pool-wide.
    pub fn in_flight(&self) -> usize {
        // ordering: Acquire — pairs with the `fulfill` decrements.
        self.shared.in_flight.load(Ordering::Acquire)
    }

    /// Stop intake, drain everything already admitted, and report.
    /// Stragglers accepted concurrently with shutdown (their jobs were
    /// still in the intake stage) are resolved with a shed outcome —
    /// no handle is ever left dangling.
    pub fn shutdown(self) -> Result<ServingReport> {
        // ordering: Release — pairs with the Acquire load in
        // `try_submit`; submitters that see `closed` bail out cleanly.
        self.shared.closed.store(true, Ordering::Release);
        self.intake.send(IntakeMsg::Shutdown).ok();
        let lane_report = match self.handle.join() {
            Ok(r) => r?,
            Err(_) => bail!("serving pool thread panicked"),
        };
        // The pool is gone; nothing concurrent remains. Sweep the
        // registry so every outstanding handle resolves.
        let leftovers: Vec<(u64, Pending)> = {
            let mut pending = self.shared.pending.lock().unwrap();
            pending.drain().collect()
        };
        for (id, p) in leftovers {
            // ordering: AcqRel — mirrors `fulfill`; nothing concurrent
            // remains at this point, the pairing is for uniformity.
            p.gate.in_flight.fetch_sub(1, Ordering::AcqRel);
            self.shared.in_flight.fetch_sub(1, Ordering::AcqRel);
            {
                let mut classes = self.shared.classes.lock().unwrap();
                let acc = &mut classes[class_index(p.class)];
                acc.shed += 1;
            }
            p.completion
                .complete(shed_outcome(id, p.stream, p.initial, "pool shut down before dispatch"));
        }
        let classes = {
            let accs = self.shared.classes.lock().unwrap();
            SloClass::all()
                .iter()
                .map(|&class| {
                    let a = &accs[class_index(class)];
                    SloClassStats {
                        class,
                        submitted: a.submitted,
                        completed: a.completed,
                        ok: a.ok,
                        failed: a.failed,
                        shed: a.shed,
                        latency: a.latency.clone(),
                    }
                })
                .collect()
        };
        Ok(ServingReport {
            lane_report,
            classes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u64) -> RegistrationOutcome {
        RegistrationOutcome {
            id,
            stream: 0,
            lane: 0,
            transform: Mat4::IDENTITY,
            rmse: 0.0,
            iterations: 1,
            stop: StopReason::Converged,
            queue_wait_ms: 0.0,
            service_ms: 1.0,
            error: None,
            attempts: 1,
        }
    }

    fn handle(id: u64) -> (Arc<Completion>, CompletionHandle) {
        let completion = Arc::new(Completion::new());
        let h = CompletionHandle {
            id,
            class: SloClass::Standard,
            inner: Arc::clone(&completion),
        };
        (completion, h)
    }

    #[test]
    fn handle_try_take_then_complete() {
        let (completion, h) = handle(7);
        assert!(!h.is_complete());
        assert!(h.try_take().is_none());
        completion.complete(outcome(7));
        assert!(h.is_complete());
        let o = h.try_take().expect("resolved");
        assert_eq!(o.id, 7);
        // The outcome moves out exactly once.
        assert!(h.try_take().is_none());
        assert!(h.is_complete());
    }

    #[test]
    fn handle_wait_blocks_until_complete() {
        let (completion, h) = handle(3);
        let t = std::thread::spawn(move || h.wait().id);
        std::thread::sleep(Duration::from_millis(10));
        completion.complete(outcome(3));
        assert_eq!(t.join().unwrap(), 3);
    }

    #[test]
    fn handle_wait_timeout_expires() {
        let (completion, h) = handle(4);
        assert!(h.wait_timeout(Duration::from_millis(5)).is_none());
        completion.complete(outcome(4));
        let o = h.wait_timeout(Duration::from_millis(5)).expect("resolved");
        assert_eq!(o.id, 4);
    }

    #[test]
    fn waker_fires_on_completion() {
        let (completion, h) = handle(5);
        let fired = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&fired);
        h.set_waker(move || flag.store(true, Ordering::SeqCst));
        assert!(!fired.load(Ordering::SeqCst));
        completion.complete(outcome(5));
        assert!(fired.load(Ordering::SeqCst));
    }

    #[test]
    fn waker_fires_immediately_when_already_complete() {
        let (completion, h) = handle(6);
        completion.complete(outcome(6));
        let fired = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&fired);
        h.set_waker(move || flag.store(true, Ordering::SeqCst));
        assert!(fired.load(Ordering::SeqCst));
    }

    #[test]
    fn shed_outcome_is_structured() {
        let o = shed_outcome(9, 2, Mat4::IDENTITY, "test reason");
        assert_eq!(o.stop, StopReason::Shed);
        assert_eq!(o.lane, usize::MAX);
        assert!(o.is_failed());
        assert!(o.error.as_deref().unwrap().contains("test reason"));
        assert!(o.rmse.is_nan());
    }

    #[test]
    fn slo_class_round_trips() {
        for class in SloClass::all() {
            let parsed: SloClass = class.name().parse().expect("round trip");
            assert_eq!(parsed, class);
        }
        assert!("realtime".parse::<SloClass>().is_err());
    }
}
