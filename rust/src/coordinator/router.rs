//! Pool-wide residency coordination: the [`AffinityRouter`] mirrors each
//! lane backend's LRU resident-target set (corrected by per-job
//! [`JobFeedback`], generation-stamped across lane restarts) and decides
//! where every job goes — warm lanes keep their keys, cold keys fill
//! free residency slots before any warm lane evicts, and stealing only
//! starts at a real backlog ([`STEAL_BACKLOG`]) with another lane idle.

/// Steal threshold: a warm lane keeps its key's jobs until it has this
/// many in flight *and* another lane sits idle. One in-flight job is
/// not a backlog — it drains sooner than a redundant target upload
/// pays off — so stealing starts at a queue two deep.
pub const STEAL_BACKLOG: usize = 2;

/// Per-job completion feedback a lane reports to the dispatcher — the
/// ground truth that corrects the [`AffinityRouter`]'s warm-set mirror
/// (see [`AffinityRouter::completed`]).
#[derive(Clone, Copy, Debug)]
pub struct JobFeedback {
    /// Lane that served the job.
    pub lane: usize,
    /// The job's target key.
    pub key: u64,
    /// The backend actually uploaded the target during this job (the
    /// lane diffs its upload counter around `align()`), so the lane now
    /// genuinely holds the key — even if the alignment later errored.
    pub uploaded: bool,
    /// The job re-activated an already-resident target (the cache-hit
    /// counter advanced): the key is device-resident and was just
    /// MRU-touched there — even if a later stage of the alignment
    /// failed, which is why this cannot be inferred from `ok` alone.
    pub hit: bool,
    /// The alignment returned `Ok`.
    pub ok: bool,
    /// The lane's backend generation the job ran under (0 until the
    /// first restart). Feedback whose generation trails the router's
    /// ([`AffinityRouter::generation`]) is *stale*: the backend it
    /// describes is gone, so it settles only the load estimate and
    /// never touches the warm/resident mirrors (see
    /// [`AffinityRouter::lane_restarted`]).
    pub generation: u64,
}

/// Pool-wide residency coordinator — the routing core of the supervised
/// dispatcher: a pure, deterministic state machine over
/// per-lane **warm key sets** (the dispatcher-side mirror of each lane
/// backend's LRU resident-target set) plus a pending-job load estimate
/// and per-lane **slot occupancy** (free vs. warm). Separated from the
/// channel plumbing so the scheduling policy is unit-testable without
/// threads, and public so the property suite can drive it against real
/// backends.
///
/// Invariants the channel loop must uphold:
/// * routing state is committed via [`Self::committed`] only **after** a
///   send succeeds (a failed `try_send` must not poison the warm sets);
/// * every served job reports [`JobFeedback`] through
///   [`Self::completed`], which *corrects* the optimistically committed
///   mirror — replaying uploads and cache hits onto the confirmed
///   resident mirror, and un-warming a key whose job failed before
///   touching residency. The corrected warm sets stay a subset of each
///   backend's [`KernelBackend::resident_epochs`] keys
///   (property-tested).
pub struct AffinityRouter {
    /// Per-lane warm target keys, LRU first / MRU last, each bounded by
    /// `slots` — uploads past capacity evict exactly like the backend.
    warm: Vec<Vec<u64>>,
    /// Keys *confirmed* device-resident per lane (LRU first), updated
    /// only by [`JobFeedback`] — the exact mirror of each backend's
    /// resident set as of its last processed completion. Distinct from
    /// the warm set: `warm` also carries optimistic, not-yet-completed
    /// commits (and drops keys conservatively on failure), while this
    /// list replays the device's own upload/activate transitions, so a
    /// device slot filled by a key the warm mirror later forgot still
    /// counts as occupied.
    resident: Vec<Vec<u64>>,
    /// Jobs sent to each lane minus completions seen.
    pending: Vec<usize>,
    /// Residency slots mirrored per lane.
    slots: usize,
    /// Round-robin cursor for tie-breaking and spill.
    rr: usize,
    /// Per-lane backend generation: bumped by [`Self::lane_restarted`]
    /// so feedback from a pre-restart backend is recognizably stale.
    gen: Vec<u64>,
    /// Lanes the supervisor declared wedged; routing avoids them until
    /// they recover (unless every lane is down).
    down: Vec<bool>,
}

impl AffinityRouter {
    /// A router over `lanes` lanes, each with `slots` residency slots
    /// (`slots` is clamped to ≥ 1).
    pub fn new(lanes: usize, slots: usize) -> Self {
        Self {
            warm: vec![Vec::new(); lanes],
            resident: vec![Vec::new(); lanes],
            pending: vec![0; lanes],
            slots: slots.max(1),
            rr: 0,
            gen: vec![0; lanes],
            down: vec![false; lanes],
        }
    }

    /// Number of lanes this router places jobs across.
    pub fn lanes(&self) -> usize {
        self.pending.len()
    }

    /// Jobs routed to `lane` and not yet completed.
    pub fn pending(&self, lane: usize) -> usize {
        self.pending[lane]
    }

    /// The mirror's warm keys of `lane`, LRU first / MRU last.
    pub fn warm_keys(&self, lane: usize) -> &[u64] {
        &self.warm[lane]
    }

    /// Backend generation the router currently expects from `lane`.
    pub fn generation(&self, lane: usize) -> u64 {
        self.gen[lane]
    }

    /// Is `lane` marked wedged/down for routing purposes?
    pub fn is_down(&self, lane: usize) -> bool {
        self.down[lane]
    }

    /// The supervisor respawned `lane`'s backend: the fresh instance
    /// holds *nothing*, so clear both the warm and confirmed-resident
    /// mirrors and bump the generation — feedback still in flight from
    /// the old backend must not resurrect the keys this wipe dropped
    /// (see [`Self::completed`]).
    pub fn lane_restarted(&mut self, lane: usize) {
        if lane >= self.lanes() {
            return;
        }
        self.warm[lane].clear();
        self.resident[lane].clear();
        self.gen[lane] += 1;
    }

    /// Mark `lane` wedged (`down = true`) or recovered: routing skips
    /// down lanes while any lane is still up.
    pub fn set_down(&mut self, lane: usize, down: bool) {
        if lane < self.lanes() {
            self.down[lane] = down;
        }
    }

    /// The supervisor drained `n` queued jobs off a wedged `lane` for
    /// re-routing: they will never feed back from there, so settle the
    /// load estimate now.
    pub fn requeued(&mut self, lane: usize, n: usize) {
        if lane < self.lanes() {
            self.pending[lane] = self.pending[lane].saturating_sub(n);
        }
    }

    /// Total jobs routed and not yet fed back, across all lanes.
    pub fn total_pending(&self) -> usize {
        self.pending.iter().sum()
    }

    /// Does the mirror say `lane` has an unoccupied residency slot — a
    /// place a cold target can land without evicting anything? Uses the
    /// larger of the optimistic warm count (committed, not yet
    /// completed) and the confirmed resident count (a slot filled by a
    /// key the warm mirror later forgot is still filled).
    pub fn has_free_slot(&self, lane: usize) -> bool {
        self.warm[lane].len().max(self.resident[lane].len()) < self.slots
    }

    /// Every *up* lane warm for `key` — after a steal there can be
    /// several — least-loaded first (ties by lane index). Down lanes
    /// are never warm candidates: their queue is not draining.
    pub fn warm_lanes(&self, key: u64) -> Vec<usize> {
        let mut v: Vec<usize> = (0..self.lanes())
            .filter(|&l| !self.down[l] && self.warm[l].contains(&key))
            .collect();
        v.sort_by_key(|&l| self.pending[l]); // stable sort keeps index order on ties
        v
    }

    /// Routing decision, in priority order:
    /// 1. **warm hit** — the least-loaded warm lane, as long as its
    ///    backlog stays under [`STEAL_BACKLOG`];
    /// 2. **steal** — every warm lane is backlogged and a lane sits
    ///    idle: the idle lane (free-slot lanes preferred) pays one extra
    ///    upload rather than serializing a same-target batch;
    /// 3. the least-loaded warm lane when nobody is idle;
    /// 4. **free slot** — a cold key goes to the least-loaded lane with
    ///    an unoccupied residency slot: filling free pool capacity
    ///    always beats evicting a warm lane's LRU key;
    /// 5. `None` — cold key, every slot on every lane occupied: the
    ///    caller spills by load (an eviction is inevitable).
    pub fn first_choice(&self, key: u64) -> Option<usize> {
        let warm = self.warm_lanes(key);
        if let Some(&best) = warm.first() {
            if self.pending[best] < STEAL_BACKLOG {
                return Some(best);
            }
            let idle = (0..self.lanes())
                .filter(|&l| !self.down[l] && self.pending[l] == 0)
                .min_by_key(|&l| !self.has_free_slot(l));
            if let Some(idle) = idle {
                return Some(idle);
            }
            return Some(best);
        }
        (0..self.lanes())
            .filter(|&l| !self.down[l] && self.has_free_slot(l))
            .min_by_key(|&l| self.pending[l])
    }

    /// Spill order for non-blocking attempts after [`Self::first_choice`]
    /// found its queue full: everyone except the already-tried lane,
    /// least-loaded first (a cold key must not queue behind a deep
    /// backlog just because a lane's cache is fresh), free-slot lanes
    /// before evicting ones at equal load, rotation order breaking the
    /// remaining ties.
    pub fn spill_order(&self, exclude: Option<usize>) -> Vec<usize> {
        let lanes = self.lanes();
        let mut order: Vec<usize> = (0..lanes)
            .map(|i| (self.rr + i) % lanes)
            .filter(|&l| Some(l) != exclude && !self.down[l])
            .collect();
        if order.is_empty() {
            // Every other lane is down: spill anywhere rather than
            // nowhere — jobs queue up and drain once a lane recovers.
            order = (0..lanes)
                .map(|i| (self.rr + i) % lanes)
                .filter(|&l| Some(l) != exclude)
                .collect();
        }
        order.sort_by_key(|&l| (self.pending[l], !self.has_free_slot(l)));
        order
    }

    /// Lane to block on when every queue is full: the least-loaded warm
    /// lane (keeps the cache hot), else the shortest queue — free-slot
    /// lanes first at equal load, rotation order on remaining ties —
    /// never a blind round-robin pick past a shorter queue.
    pub fn blocking_choice(&self, key: u64) -> usize {
        if let Some(&l) = self.warm_lanes(key).first() {
            return l;
        }
        let lanes = self.lanes();
        (0..lanes)
            .map(|i| (self.rr + i) % lanes)
            .min_by_key(|&l| (self.down[l], self.pending[l], !self.has_free_slot(l)))
            .unwrap_or(0)
    }

    /// Touch `key` MRU on `lane`'s mirror, evicting past the slot count
    /// exactly like the backend's LRU set.
    fn touch_warm(&mut self, lane: usize, key: u64) {
        let w = &mut self.warm[lane];
        if let Some(i) = w.iter().position(|&k| k == key) {
            w.remove(i);
        }
        w.push(key);
        while w.len() > self.slots {
            w.remove(0);
        }
    }

    /// A job with `key` was *successfully* sent to `lane`: bump its
    /// load, optimistically mark the key warm (MRU — so back-to-back
    /// same-key jobs keep their affinity before the first completes),
    /// advance the round-robin cursor. The optimism is corrected by
    /// [`Self::completed`] once the job's real outcome is known.
    pub fn committed(&mut self, lane: usize, key: u64) {
        self.pending[lane] += 1;
        self.touch_warm(lane, key);
        self.rr = (lane + 1) % self.lanes();
    }

    /// Replay a confirmed device transition for `key` on `lane`'s
    /// resident mirror — insert/touch MRU, and on capacity pressure
    /// evict the resident LRU exactly like the device did, dropping the
    /// evicted key from the warm mirror too (it is no longer on the
    /// card, whatever the optimistic commits said).
    fn confirm_resident(&mut self, lane: usize, key: u64) {
        let r = &mut self.resident[lane];
        if let Some(i) = r.iter().position(|&k| k == key) {
            r.remove(i);
        }
        r.push(key);
        while self.resident[lane].len() > self.slots {
            let evicted = self.resident[lane].remove(0);
            self.warm[lane].retain(|&k| k != evicted);
        }
        self.touch_warm(lane, key);
    }

    /// Apply one job's [`JobFeedback`]: drop the lane's load estimate,
    /// then correct the mirror from the ground truth instead of keeping
    /// the commit-time guess:
    ///
    /// * **uploaded** (even on a failed alignment — the device holds
    ///   the target regardless) or **cache hit** (the key was resident
    ///   and just MRU-touched, even if a later stage of the job
    ///   failed): replay the transition on the confirmed resident
    ///   mirror, including the device's own LRU eviction when an
    ///   upload ran at capacity — so the mirror never retains a key
    ///   the device dropped.
    /// * **failed without touching residency** (neither uploaded nor
    ///   hit): un-warm the key the optimistic commit guessed — the
    ///   backend never gained it — while leaving the confirmed
    ///   resident set untouched (failure changes no device slot).
    ///
    /// Feedback from a *stale generation* (the lane's backend was
    /// respawned since the job ran, see [`Self::lane_restarted`])
    /// settles the load estimate only: the backend it describes is
    /// gone, so replaying it onto the mirror would resurrect keys the
    /// restart wiped.
    pub fn completed(&mut self, fb: JobFeedback) {
        if fb.lane >= self.lanes() {
            return;
        }
        self.pending[fb.lane] = self.pending[fb.lane].saturating_sub(1);
        if fb.generation != self.gen[fb.lane] {
            return;
        }
        if fb.uploaded || fb.hit {
            self.confirm_resident(fb.lane, fb.key);
        } else if !fb.ok {
            self.warm[fb.lane].retain(|&k| k != fb.key);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // --- AffinityRouter: deterministic scheduling-policy harness ---

    /// Shorthand for completion feedback in the router tests.
    fn fb(lane: usize, key: u64, uploaded: bool, hit: bool, ok: bool) -> JobFeedback {
        JobFeedback {
            lane,
            key,
            uploaded,
            hit,
            ok,
            generation: 0,
        }
    }

    #[test]
    fn stale_generation_feedback_does_not_resurrect_warm_keys() {
        let mut r = AffinityRouter::new(2, 2);
        // Lane 0 serves key 7 and the feedback confirms residency.
        r.committed(0, 7);
        r.completed(fb(0, 7, true, false, true));
        assert_eq!(r.warm_keys(0), &[7]);
        // Two more jobs for the key are in flight when the lane's
        // backend is respawned: the restart clears the mirror and bumps
        // the generation...
        r.committed(0, 7);
        r.committed(0, 7);
        r.lane_restarted(0);
        assert_eq!(r.generation(0), 1);
        assert!(r.warm_keys(0).is_empty(), "restart must clear warm keys");
        assert_eq!(r.pending(0), 2);
        // ...so feedback from the old backend (generation 0) settles the
        // load estimate but must NOT mark the key warm — the new backend
        // holds nothing.
        r.completed(fb(0, 7, true, true, true));
        assert_eq!(r.pending(0), 1);
        assert!(
            r.warm_keys(0).is_empty(),
            "stale-generation feedback resurrected a warm key"
        );
        // Current-generation feedback is trusted again.
        let mut current = fb(0, 7, true, false, true);
        current.generation = 1;
        r.completed(current);
        assert_eq!(r.pending(0), 0);
        assert_eq!(r.warm_keys(0), &[7]);
    }

    #[test]
    fn down_lanes_are_routed_around_until_recovery() {
        let mut r = AffinityRouter::new(2, 1);
        // Key 9 is warm on lane 1, which then gets marked down.
        r.committed(1, 9);
        r.completed(fb(1, 9, true, false, true));
        r.set_down(1, true);
        assert!(r.is_down(1));
        // Warm affinity must not route to a down lane...
        let choice = r.first_choice(9);
        assert_ne!(choice, Some(1), "routed a job to a down lane");
        // ...and the spill order skips it while any other lane is up.
        assert!(!r.spill_order(None).contains(&1));
        // Recovery restores warm affinity (the backend kept its cache:
        // down ≠ restarted).
        r.set_down(1, false);
        assert_eq!(r.first_choice(9), Some(1));
    }

    #[test]
    fn router_reuses_every_warm_lane_after_a_steal() {
        let mut r = AffinityRouter::new(2, 2);
        // Cold key A: both lanes have free slots — least-loaded wins
        // (tie → lane 0), no spill needed.
        assert_eq!(r.first_choice(0xA), Some(0));
        r.committed(0, 0xA);
        r.committed(0, 0xA); // backlog of 2 on the warm lane
        // Real backlog + idle lane 1 → steal to lane 1.
        assert_eq!(r.first_choice(0xA), Some(1));
        r.committed(1, 0xA);
        // Both lanes are now warm for A. Lane 1 drains first: the
        // dispatcher must see it as a warm candidate — the old
        // `position()` scan only ever found lane 0.
        r.completed(fb(1, 0xA, true, false, true));
        assert_eq!(r.warm_lanes(0xA), vec![1, 0]);
        assert_eq!(r.first_choice(0xA), Some(1), "least-loaded warm lane");
        // Nobody idle: still route to the least-loaded *warm* lane
        // rather than blocking round-robin.
        r.committed(1, 0xA); // pending: lane0=2, lane1=1
        assert_eq!(r.first_choice(0xA), Some(1));
    }

    #[test]
    fn router_steals_only_on_real_backlog() {
        let mut r = AffinityRouter::new(2, 2);
        r.committed(0, 0xA);
        // One in-flight job is NOT a backlog: the old router stole to
        // the idle lane here, paying a redundant target upload.
        assert_eq!(r.first_choice(0xA), Some(0), "no steal at pending 1");
        r.committed(0, 0xA);
        // Two deep with an idle lane → steal.
        assert_eq!(r.first_choice(0xA), Some(1));
        // No idle lane → stay on the least-loaded warm lane.
        r.committed(1, 0xB);
        assert_eq!(r.first_choice(0xA), Some(0));
    }

    #[test]
    fn router_routes_cold_keys_to_free_slots_before_evicting() {
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        r.completed(fb(0, 0xA, true, false, true));
        // Cold key B: lane 0 is idle but its only slot is warm; lane 1
        // has the free slot — filling it beats evicting A.
        assert!(!r.has_free_slot(0));
        assert!(r.has_free_slot(1));
        assert_eq!(r.first_choice(0xB), Some(1));
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, true, false, true));
        // Every slot occupied → None: the channel loop spills by load
        // (an eviction is now inevitable).
        assert_eq!(r.first_choice(0xC), None);
        assert_eq!(r.warm_lanes(0xA), vec![0], "A untouched on its lane");
    }

    #[test]
    fn failed_upload_feedback_unwarms_the_mirror() {
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        assert_eq!(r.warm_lanes(0xA), vec![0], "optimistic commit");
        // The job failed before its target upload: the backend never
        // gained A, so the mirror must not keep claiming it.
        r.completed(fb(0, 0xA, false, false, false));
        assert!(r.warm_lanes(0xA).is_empty(), "failed upload un-warms");
        assert!(r.has_free_slot(0), "slot freed for the next cold key");
        // A failed alignment whose upload DID land keeps the key warm —
        // the device holds the target regardless of the ICP error.
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, true, false, false));
        assert_eq!(r.warm_lanes(0xB), vec![1]);
        // A cache-hit completion confirms warmth.
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, false, true, true));
        assert_eq!(r.warm_lanes(0xB), vec![1]);
    }

    #[test]
    fn router_warm_sets_are_lru_bounded_like_the_backend() {
        let mut r = AffinityRouter::new(1, 2);
        r.committed(0, 0xA);
        r.committed(0, 0xB);
        assert_eq!(r.warm_lanes(0xA), vec![0]);
        // A third key evicts the LRU key (A), not the MRU one.
        r.committed(0, 0xC);
        assert!(r.warm_lanes(0xA).is_empty(), "A evicted");
        assert_eq!(r.warm_lanes(0xB), vec![0]);
        assert_eq!(r.warm_lanes(0xC), vec![0]);
        // Re-touching B keeps it MRU: D evicts C.
        r.committed(0, 0xB);
        r.committed(0, 0xD);
        assert!(r.warm_lanes(0xC).is_empty());
        assert_eq!(r.warm_lanes(0xB), vec![0]);
    }

    #[test]
    fn router_blocking_choice_prefers_warmth_then_shortest_queue() {
        let mut r = AffinityRouter::new(3, 2);
        r.committed(0, 0xA);
        r.committed(0, 0xA);
        r.committed(1, 0xB);
        // Key A: lane 0 is warm, so block there even though it is the
        // longest queue (the cache hit outweighs one queue slot).
        assert_eq!(r.blocking_choice(0xA), 0);
        // Cold key: shortest queue wins (lane 2 is empty) — the old
        // fall-through blocked on the round-robin cursor regardless.
        assert_eq!(r.blocking_choice(0xF), 2);
        // And among equals the rotation cursor breaks the tie.
        r.committed(2, 0xC); // pending now [2, 1, 1], rr = 0
        assert_eq!(r.blocking_choice(0xF), 1);
    }

    #[test]
    fn router_spill_orders_by_load_and_skips_the_tried_lane() {
        let mut r = AffinityRouter::new(3, 2);
        r.committed(1, 0xA); // pending [0,1,0]
        r.committed(2, 0xB);
        r.committed(2, 0xC); // pending [0,1,2]
        // Load first: a fresh (cache-empty) lane does not excuse a deep
        // backlog — the old order let a cold key queue behind lane 2
        // just because its cache was empty.
        assert_eq!(r.spill_order(None), vec![0, 1, 2]);
        // The lane whose queue already returned Full is skipped, not
        // re-attempted.
        assert_eq!(r.spill_order(Some(0)), vec![1, 2]);
        // At equal load, a free residency slot breaks the tie: spilling
        // where nothing needs evicting beats spilling onto a warm slot.
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        r.committed(1, 0xB);
        r.completed(fb(0, 0xA, true, false, true)); // lane 0: idle, slot warm
        r.completed(fb(1, 0xB, false, false, false)); // lane 1: idle, slot free
        assert_eq!(r.spill_order(None), vec![1, 0]);
    }
}
