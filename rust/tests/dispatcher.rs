//! Integration tests for the lane-pool dispatcher: failed jobs must be
//! contained (one bad job cannot kill its lane, let alone the pool),
//! multi-target (tile ping-pong) workloads must stay bit-identical
//! across `lanes = 1` vs `lanes = K`, and warm-lane accounting must
//! conserve work. The deterministic routing-policy harness (warm-lane
//! reuse after steals, LRU warm sets, blocking choice) lives next to
//! `AffinityRouter` in `coordinator::tests`.

use fpps::coordinator::{
    run_registration_batch, tiled_localization_jobs, LaneIcpConfig, PipelineConfig,
    RegistrationJob,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{KdTreeCpuBackend, NativeSimBackend};
use fpps::icp::StopReason;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;
use std::sync::Arc;

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

fn tiny_sequence(frames: usize) -> Sequence {
    let spec = sequence_specs()[3].clone(); // residential: gentle
    Sequence::synthetic(spec, frames, 11, LidarConfig::tiny())
}

/// Jobs alternating between two shared maps, plus one poison job with an
/// empty source cloud that makes `align()` error.
fn jobs_with_one_poison(n: usize) -> Vec<RegistrationJob> {
    let map_a = Arc::new(structured_cloud(600, 300));
    let map_b = Arc::new(structured_cloud(600, 301));
    let gt = Mat4::from_rt(Mat3::rot_z(0.01), Vec3::new(0.08, -0.02, 0.0));
    (0..n as u64)
        .map(|k| {
            let map = if k % 2 == 0 { &map_a } else { &map_b };
            let source = if k == 2 {
                PointCloud::new() // align() bails: "source/target cloud is empty"
            } else {
                let mut rng = Pcg32::new(310 + k);
                let mut s = map.transformed(&gt.inverse_rigid());
                s.add_noise(0.005, &mut rng);
                s.random_sample(300, &mut rng)
            };
            RegistrationJob::new(k, 0, source, Arc::clone(map), Mat4::IDENTITY)
        })
        .collect()
}

/// A single failing job is contained in its outcome; its lane keeps
/// draining and every other job of the batch completes normally.
#[test]
fn failed_job_does_not_kill_its_lane() {
    for lanes in [1usize, 2] {
        let report = run_registration_batch(
            jobs_with_one_poison(8),
            lanes,
            4,
            LaneIcpConfig::default(),
            |_| Ok(NativeSimBackend::new()),
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 8, "{lanes} lanes: all jobs drained");
        assert_eq!(report.failed_jobs(), 1);
        for o in &report.outcomes {
            if o.id == 2 {
                assert!(o.is_failed());
                // Infrastructure failures get their own stop reason —
                // never conflated with a data-quality signal.
                assert_eq!(o.stop, StopReason::Failed);
                let msg = o.error.as_deref().unwrap();
                assert!(msg.contains("empty"), "contextful error, got {msg:?}");
                assert!(o.rmse.is_nan());
                assert_eq!(o.iterations, 0);
                // The failed outcome hands back the job's prior.
                assert_eq!(o.transform.m, Mat4::IDENTITY.m);
            } else {
                assert_ne!(o.stop, StopReason::Failed);
                assert!(!o.is_failed(), "job {} poisoned by neighbour", o.id);
                assert!(o.rmse.is_finite());
                assert!(o.iterations >= 1);
            }
        }
        // The per-lane failure tally matches the outcomes.
        let failed_by_lane: usize = report.lanes.iter().map(|l| l.failed).sum();
        assert_eq!(failed_by_lane, 1);
        let served: usize = report.lanes.iter().map(|l| l.jobs).sum();
        assert_eq!(served, 8);
    }
}

/// Failure containment is deterministic: the same poisoned batch yields
/// bit-identical outcomes (including the failure) on 1 vs K lanes.
#[test]
fn poisoned_batch_is_bit_identical_across_lane_counts() {
    let one = run_registration_batch(
        jobs_with_one_poison(8),
        1,
        2,
        LaneIcpConfig::default(),
        |_| Ok(NativeSimBackend::new()),
    )
    .unwrap();
    let many = run_registration_batch(
        jobs_with_one_poison(8),
        3,
        2,
        LaneIcpConfig::default(),
        |_| Ok(NativeSimBackend::new()),
    )
    .unwrap();
    for (a, b) in one.outcomes.iter().zip(many.outcomes.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.is_failed(), b.is_failed(), "job {}", a.id);
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
        assert_eq!(a.iterations, b.iterations);
    }
}

/// Tile ping-pong over the pool: `lanes = 1` vs `lanes = K` produce
/// bit-identical transforms on a seeded tiled workload, and the
/// multi-slot residency keeps pool-wide uploads bounded by
/// tiles × lanes (never one per scan).
#[test]
fn tiled_workload_bit_identical_across_lane_counts() {
    let seq = tiny_sequence(8);
    let cfg = PipelineConfig {
        source_sample: 512,
        target_capacity: 4096,
        ..Default::default()
    };
    let icp_cfg = LaneIcpConfig {
        max_iteration_count: 30,
        ..Default::default()
    };
    let tiles = 2;

    let one = run_registration_batch(
        tiled_localization_jobs(&seq, 8, tiles, &cfg).unwrap().jobs,
        1,
        4,
        icp_cfg,
        |_| Ok(KdTreeCpuBackend::new()),
    )
    .unwrap();
    let two = run_registration_batch(
        tiled_localization_jobs(&seq, 8, tiles, &cfg).unwrap().jobs,
        2,
        8,
        icp_cfg,
        |_| Ok(KdTreeCpuBackend::new()),
    )
    .unwrap();

    assert_eq!(one.outcomes.len(), 8);
    assert_eq!(two.outcomes.len(), 8);
    for (a, b) in one.outcomes.iter().zip(two.outcomes.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
        assert_eq!(a.iterations, b.iterations);
    }

    // One lane sees both submaps exactly once: 2 uploads, 6 hits.
    let uploads1: usize = one.lanes.iter().map(|l| l.target_uploads).sum();
    let hits1: usize = one.lanes.iter().map(|l| l.target_hits).sum();
    assert_eq!(uploads1, tiles, "single lane: one upload per tile");
    assert_eq!(uploads1 + hits1, 8);

    // K lanes: at most tiles × lanes uploads, still never per scan.
    let uploads2: usize = two.lanes.iter().map(|l| l.target_uploads).sum();
    let hits2: usize = two.lanes.iter().map(|l| l.target_hits).sum();
    assert!(
        (tiles..=tiles * 2).contains(&uploads2),
        "uploads {uploads2} outside [tiles, tiles x lanes]"
    );
    assert_eq!(uploads2 + hits2, 8);
}

/// The pool honors backend-configured slot counts end to end: lanes
/// report their real residency to the dispatcher, and with one slot the
/// ping-pong thrashes by design — every tile switch re-uploads, exactly
/// the behavior `--slots 1` exists to demonstrate.
#[test]
fn single_slot_backends_thrash_on_tile_ping_pong() {
    let seq = tiny_sequence(6);
    let cfg = PipelineConfig {
        source_sample: 512,
        target_capacity: 4096,
        ..Default::default()
    };
    let report = run_registration_batch(
        tiled_localization_jobs(&seq, 6, 2, &cfg).unwrap().jobs,
        1,
        4,
        LaneIcpConfig {
            max_iteration_count: 30,
            ..Default::default()
        },
        |_| Ok(KdTreeCpuBackend::with_residency_slots(1)),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 6);
    let uploads: usize = report.lanes.iter().map(|l| l.target_uploads).sum();
    assert_eq!(uploads, 6, "one slot: A,B,A,B,… re-uploads every switch");
    assert_eq!(report.lanes[0].resident_targets, 1);
}
