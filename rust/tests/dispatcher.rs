//! Integration tests for the lane-pool dispatcher: failed jobs must be
//! contained (one bad job cannot kill its lane, let alone the pool),
//! multi-target (tile ping-pong) workloads must stay bit-identical
//! across `lanes = 1` vs `lanes = K`, warm-lane accounting must
//! conserve work, cold keys must fill free residency slots before any
//! warm lane evicts, the router mirror must un-warm keys whose upload
//! failed, and oversized maps must hit the configured admission policy
//! instead of silent behavior. The deterministic routing-policy harness
//! (steal thresholds, LRU warm sets, spill/blocking order) lives next
//! to `AffinityRouter` in `coordinator::tests`.

use fpps::coordinator::{
    localization_jobs, run_registration_batch, tiled_localization_jobs, AdmissionError,
    AdmissionPolicy, AffinityRouter, JobFeedback, LaneIcpConfig, PipelineConfig,
    RegistrationJob,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{KdTreeCpuBackend, NativeSimBackend};
use fpps::icp::StopReason;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;
use std::sync::Arc;

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

fn tiny_sequence(frames: usize) -> Sequence {
    let spec = sequence_specs()[3].clone(); // residential: gentle
    Sequence::synthetic(spec, frames, 11, LidarConfig::tiny())
}

/// Jobs alternating between two shared maps, plus one poison job with an
/// empty source cloud that makes `align()` error.
fn jobs_with_one_poison(n: usize) -> Vec<RegistrationJob> {
    let map_a = Arc::new(structured_cloud(600, 300));
    let map_b = Arc::new(structured_cloud(600, 301));
    let gt = Mat4::from_rt(Mat3::rot_z(0.01), Vec3::new(0.08, -0.02, 0.0));
    (0..n as u64)
        .map(|k| {
            let map = if k % 2 == 0 { &map_a } else { &map_b };
            let source = if k == 2 {
                PointCloud::new() // align() bails: "source/target cloud is empty"
            } else {
                let mut rng = Pcg32::new(310 + k);
                let mut s = map.transformed(&gt.inverse_rigid());
                s.add_noise(0.005, &mut rng);
                s.random_sample(300, &mut rng)
            };
            RegistrationJob::new(k, 0, source, Arc::clone(map), Mat4::IDENTITY)
        })
        .collect()
}

/// A single failing job is contained in its outcome; its lane keeps
/// draining and every other job of the batch completes normally.
#[test]
fn failed_job_does_not_kill_its_lane() {
    for lanes in [1usize, 2] {
        let report = run_registration_batch(
            jobs_with_one_poison(8),
            lanes,
            4,
            LaneIcpConfig::default(),
            |_| Ok(NativeSimBackend::new()),
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 8, "{lanes} lanes: all jobs drained");
        assert_eq!(report.failed_jobs(), 1);
        for o in &report.outcomes {
            if o.id == 2 {
                assert!(o.is_failed());
                // Infrastructure failures get their own stop reason —
                // never conflated with a data-quality signal.
                assert_eq!(o.stop, StopReason::Failed);
                let msg = o.error.as_deref().unwrap();
                assert!(msg.contains("empty"), "contextful error, got {msg:?}");
                assert!(o.rmse.is_nan());
                assert_eq!(o.iterations, 0);
                // The failed outcome hands back the job's prior.
                assert_eq!(o.transform.m, Mat4::IDENTITY.m);
            } else {
                assert_ne!(o.stop, StopReason::Failed);
                assert!(!o.is_failed(), "job {} poisoned by neighbour", o.id);
                assert!(o.rmse.is_finite());
                assert!(o.iterations >= 1);
            }
        }
        // The per-lane failure tally matches the outcomes.
        let failed_by_lane: usize = report.lanes.iter().map(|l| l.failed).sum();
        assert_eq!(failed_by_lane, 1);
        let served: usize = report.lanes.iter().map(|l| l.jobs).sum();
        assert_eq!(served, 8);
    }
}

/// Failure containment is deterministic: the same poisoned batch yields
/// bit-identical outcomes (including the failure) on 1 vs K lanes.
#[test]
fn poisoned_batch_is_bit_identical_across_lane_counts() {
    let one = run_registration_batch(
        jobs_with_one_poison(8),
        1,
        2,
        LaneIcpConfig::default(),
        |_| Ok(NativeSimBackend::new()),
    )
    .unwrap();
    let many = run_registration_batch(
        jobs_with_one_poison(8),
        3,
        2,
        LaneIcpConfig::default(),
        |_| Ok(NativeSimBackend::new()),
    )
    .unwrap();
    for (a, b) in one.outcomes.iter().zip(many.outcomes.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.is_failed(), b.is_failed(), "job {}", a.id);
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
        assert_eq!(a.iterations, b.iterations);
    }
}

/// Tile ping-pong over the pool: `lanes = 1` vs `lanes = K` produce
/// bit-identical transforms on a seeded tiled workload, and the
/// multi-slot residency keeps pool-wide uploads bounded by
/// tiles × lanes (never one per scan).
#[test]
fn tiled_workload_bit_identical_across_lane_counts() {
    let seq = tiny_sequence(8);
    let cfg = PipelineConfig {
        source_sample: 512,
        target_capacity: 4096,
        ..Default::default()
    };
    let icp_cfg = LaneIcpConfig {
        max_iteration_count: 30,
        ..Default::default()
    };
    let tiles = 2;

    let one = run_registration_batch(
        tiled_localization_jobs(&seq, 8, tiles, &cfg).unwrap().jobs,
        1,
        4,
        icp_cfg,
        |_| Ok(KdTreeCpuBackend::new()),
    )
    .unwrap();
    let two = run_registration_batch(
        tiled_localization_jobs(&seq, 8, tiles, &cfg).unwrap().jobs,
        2,
        8,
        icp_cfg,
        |_| Ok(KdTreeCpuBackend::new()),
    )
    .unwrap();

    assert_eq!(one.outcomes.len(), 8);
    assert_eq!(two.outcomes.len(), 8);
    for (a, b) in one.outcomes.iter().zip(two.outcomes.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
        assert_eq!(a.iterations, b.iterations);
    }

    // One lane sees both submaps exactly once: 2 uploads, 6 hits.
    let uploads1: usize = one.lanes.iter().map(|l| l.target_uploads).sum();
    let hits1: usize = one.lanes.iter().map(|l| l.target_hits).sum();
    assert_eq!(uploads1, tiles, "single lane: one upload per tile");
    assert_eq!(uploads1 + hits1, 8);

    // K lanes: at most tiles × lanes uploads, still never per scan.
    let uploads2: usize = two.lanes.iter().map(|l| l.target_uploads).sum();
    let hits2: usize = two.lanes.iter().map(|l| l.target_hits).sum();
    assert!(
        (tiles..=tiles * 2).contains(&uploads2),
        "uploads {uploads2} outside [tiles, tiles x lanes]"
    );
    assert_eq!(uploads2 + hits2, 8);
}

/// Acceptance criterion of the residency coordinator: a cold-key job is
/// routed to a lane with a free residency slot whenever one exists. Four
/// distinct single-job keys over 2 lanes × 2 slots exactly fill the
/// pool, so — regardless of completion timing — coordinated routing
/// uploads each key once and never evicts, while the same workload on
/// one lane (2 slots) must evict twice. Both are bit-identical.
#[test]
fn cold_keys_fill_free_slots_before_evicting() {
    let maps: Vec<Arc<PointCloud>> = (0..4)
        .map(|k| Arc::new(structured_cloud(500, 400 + k)))
        .collect();
    let gt = Mat4::from_rt(Mat3::rot_z(0.015), Vec3::new(0.06, -0.03, 0.0));
    let build = |maps: &[Arc<PointCloud>]| -> Vec<RegistrationJob> {
        maps.iter()
            .enumerate()
            .map(|(k, map)| {
                let mut rng = Pcg32::new(420 + k as u64);
                let mut s = map.transformed(&gt.inverse_rigid());
                s.add_noise(0.005, &mut rng);
                RegistrationJob::new(
                    k as u64,
                    0,
                    s.random_sample(250, &mut rng),
                    Arc::clone(map),
                    Mat4::IDENTITY,
                )
            })
            .collect()
    };

    let pool = run_registration_batch(
        build(&maps),
        2,
        8,
        LaneIcpConfig::default(),
        |_| Ok(KdTreeCpuBackend::with_residency_slots(2)),
    )
    .unwrap();
    let uploads: usize = pool.lanes.iter().map(|l| l.target_uploads).sum();
    let hits: usize = pool.lanes.iter().map(|l| l.target_hits).sum();
    let evictions: usize = pool.lanes.iter().map(|l| l.target_evictions).sum();
    let resident: usize = pool.lanes.iter().map(|l| l.resident_targets).sum();
    assert_eq!(uploads, 4, "each cold key uploads exactly once");
    assert_eq!(hits, 0);
    assert_eq!(
        evictions, 0,
        "no eviction while the pool had free residency slots"
    );
    assert_eq!(resident, 4, "all four keys end resident across the pool");

    // One lane with the same per-backend capacity cannot avoid evicting.
    let single = run_registration_batch(
        build(&maps),
        1,
        8,
        LaneIcpConfig::default(),
        |_| Ok(KdTreeCpuBackend::with_residency_slots(2)),
    )
    .unwrap();
    let s_evictions: usize = single.lanes.iter().map(|l| l.target_evictions).sum();
    assert_eq!(s_evictions, 2, "4 keys through 2 slots evict twice");
    // Placement is invisible to numerics: bit-identical either way.
    for (a, b) in single.outcomes.iter().zip(pool.outcomes.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
        assert_eq!(a.iterations, b.iterations);
    }
}

/// Satellite regression (through the public router API): `committed()`
/// marks a key warm optimistically, so a job that fails *before* its
/// target upload must be un-warmed by its completion feedback — the old
/// mirror kept claiming warmth the backend never gained, occupying a
/// phantom slot and steering later same-key jobs to a cache that did
/// not exist.
#[test]
fn failed_upload_unwarms_the_router_mirror() {
    let mut r = AffinityRouter::new(2, 1);
    assert_eq!(r.first_choice(0xA), Some(0), "cold key fills a free slot");
    r.committed(0, 0xA);
    assert_eq!(r.warm_lanes(0xA), vec![0], "optimistic until feedback");
    // Upload never happened (e.g. empty-source bail before the DMA).
    r.completed(JobFeedback {
        lane: 0,
        key: 0xA,
        uploaded: false,
        hit: false,
        ok: false,
        generation: 0,
    });
    assert!(r.warm_lanes(0xA).is_empty(), "mirror corrected");
    assert!(r.has_free_slot(0), "the slot is free again");
    // The next cold key takes that freed slot instead of lane 1's.
    assert_eq!(r.first_choice(0xB), Some(0));
    // An upload that landed keeps the key warm even on a failed job.
    r.committed(1, 0xC);
    r.completed(JobFeedback {
        lane: 1,
        key: 0xC,
        uploaded: true,
        hit: false,
        ok: false,
        generation: 0,
    });
    assert_eq!(r.warm_lanes(0xC), vec![1], "device holds it regardless");
    // So is a key whose job *hit* the cache and then failed — the
    // device still holds (and just MRU-touched) it.
    r.committed(1, 0xC);
    r.completed(JobFeedback {
        lane: 1,
        key: 0xC,
        uploaded: false,
        hit: true,
        ok: false,
        generation: 0,
    });
    assert_eq!(r.warm_lanes(0xC), vec![1], "hit-then-fail stays warm");
    // And the next same-key job is a warm hit on that lane, not a
    // re-upload elsewhere.
    assert_eq!(r.first_choice(0xC), Some(1));
}

/// Bit-identity under the full mix: `lanes = 3` with free-slot fills,
/// warm hits, steals, pool-capacity evictions and one poisoned job
/// matches `lanes = 1` bit for bit, and upload/hit accounting conserves
/// jobs (the poisoned job — which fails before its upload — counts in
/// neither).
#[test]
fn coordinated_pool_is_bit_identical_to_single_lane_under_mixed_routing() {
    let maps: Vec<Arc<PointCloud>> = (0..8)
        .map(|k| Arc::new(structured_cloud(500, 500 + k)))
        .collect();
    let gt = Mat4::from_rt(Mat3::rot_z(0.01), Vec3::new(0.08, -0.02, 0.0));
    let build = |maps: &[Arc<PointCloud>]| -> Vec<RegistrationJob> {
        (0..17u64)
            .map(|k| {
                let map = &maps[(k % 8) as usize];
                let source = if k == 5 {
                    PointCloud::new() // poison: align() bails pre-upload
                } else {
                    let mut rng = Pcg32::new(530 + k);
                    let mut s = map.transformed(&gt.inverse_rigid());
                    s.add_noise(0.005, &mut rng);
                    s.random_sample(250, &mut rng)
                };
                RegistrationJob::new(k, 0, source, Arc::clone(map), Mat4::IDENTITY)
            })
            .collect()
    };
    let run = |jobs, lanes| {
        run_registration_batch(jobs, lanes, 4, LaneIcpConfig::default(), |_| {
            Ok(KdTreeCpuBackend::with_residency_slots(2))
        })
        .unwrap()
    };
    let one = run(build(&maps), 1);
    let many = run(build(&maps), 3);
    assert_eq!(one.outcomes.len(), 17);
    assert_eq!(many.outcomes.len(), 17);
    assert_eq!(one.failed_jobs(), 1);
    assert_eq!(many.failed_jobs(), 1);
    for report in [&one, &many] {
        let uploads: usize = report.lanes.iter().map(|l| l.target_uploads).sum();
        let hits: usize = report.lanes.iter().map(|l| l.target_hits).sum();
        assert_eq!(
            uploads + hits,
            16,
            "every non-poisoned job either uploads or hits"
        );
    }
    for (a, b) in one.outcomes.iter().zip(many.outcomes.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.is_failed(), b.is_failed(), "job {}", a.id);
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
        assert_eq!(a.iterations, b.iterations);
    }
}

/// Acceptance criterion of residency-aware admission: an oversized map
/// triggers the configured policy — a structured, downcastable
/// rejection or an explicit, recorded downsample — never the old silent
/// shrink.
#[test]
fn oversized_map_triggers_the_admission_policy() {
    let seq = tiny_sequence(4);
    let base = PipelineConfig {
        source_sample: 128,
        target_capacity: 100, // far below the 4-scan union
        ..Default::default()
    };
    // Default policy: downsample-to-fit, with the decision recorded.
    let w = localization_jobs(&seq, 4, &base).unwrap();
    assert!(w.map.len() <= 100);
    assert_eq!(w.admission.policy, AdmissionPolicy::DownsampleToFit);
    assert!(w.admission.downsampled());
    assert!(w.admission.original_points > 100);
    assert_eq!(w.admission.admitted_points, w.map.len());
    assert_eq!(w.admission.slot_capacity, 100);
    assert!(w.admission.footprint.bytes >= w.admission.footprint.padded_points as u64 * 16);

    // Reject: a structured error carrying the hwmodel footprint.
    let reject = PipelineConfig {
        admission: AdmissionPolicy::Reject,
        ..base
    };
    let err = localization_jobs(&seq, 4, &reject).unwrap_err();
    let adm = err
        .downcast_ref::<AdmissionError>()
        .expect("structured AdmissionError, downcastable through anyhow");
    assert!(adm.points > adm.slot_capacity);
    assert_eq!(adm.slot_capacity, 100);
    assert!(adm.padded_points >= adm.points);
    assert_eq!(adm.footprint_bytes, adm.padded_points as u64 * 16);
    let msg = format!("{err:#}");
    assert!(msg.contains("residency slot"), "{msg}");

    // The tiled workload admits per submap and rejects the same way.
    assert!(tiled_localization_jobs(&seq, 4, 2, &reject).is_err());
    let tiled = tiled_localization_jobs(&seq, 4, 2, &base).unwrap();
    assert_eq!(tiled.admissions.len(), 2);
    for (m, adm) in tiled.maps.iter().zip(&tiled.admissions) {
        assert_eq!(adm.admitted_points, m.len());
        assert!(m.len() <= 100);
    }
}

/// The pool honors backend-configured slot counts end to end: lanes
/// report their real residency to the dispatcher, and with one slot the
/// ping-pong thrashes by design — every tile switch re-uploads, exactly
/// the behavior `--slots 1` exists to demonstrate.
#[test]
fn single_slot_backends_thrash_on_tile_ping_pong() {
    let seq = tiny_sequence(6);
    let cfg = PipelineConfig {
        source_sample: 512,
        target_capacity: 4096,
        ..Default::default()
    };
    let report = run_registration_batch(
        tiled_localization_jobs(&seq, 6, 2, &cfg).unwrap().jobs,
        1,
        4,
        LaneIcpConfig {
            max_iteration_count: 30,
            ..Default::default()
        },
        |_| Ok(KdTreeCpuBackend::with_residency_slots(1)),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 6);
    let uploads: usize = report.lanes.iter().map(|l| l.target_uploads).sum();
    assert_eq!(uploads, 6, "one slot: A,B,A,B,… re-uploads every switch");
    assert_eq!(report.lanes[0].resident_targets, 1);
}
