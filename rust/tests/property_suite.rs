//! Cross-module property tests — artifact-free invariants that tie the
//! substrates together (complementing the per-module unit tests and the
//! artifact-backed integration suite).

use fpps::coordinator::{preprocess, AffinityRouter, JobFeedback, PipelineConfig};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{FppsIcp, KernelBackend, NativeSimBackend};
use fpps::icp::{IcpParams, SearchStrategy};
use fpps::kdtree::KdTree;
use fpps::math::{kabsch_from_pairs, Mat3, Mat4, Vec3};
use fpps::nn;
use fpps::pointcloud::{io, PointCloud};
use fpps::prop::{default_cases, forall};
use fpps::rng::Pcg32;

fn random_cloud(n: usize, seed: u64, spread: f32) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for _ in 0..n {
        c.push([
            rng.range(-spread, spread),
            rng.range(-spread, spread),
            rng.range(-spread / 10.0, spread / 10.0),
        ]);
    }
    c
}

// ---------- NN strategy agreement ----------

#[test]
fn kernel_mirror_agrees_with_kdtree_everywhere() {
    // Three independent exact-NN implementations (kd-tree with
    // backtracking, linear scan, blocked kernel dataflow) must agree on
    // the neighbour *distance* for every query (indices may differ only
    // on exact ties).
    forall(default_cases(15), |g| {
        let n = g.usize_range(1, 200);
        let m = g.usize_range(1, 600);
        let queries = random_cloud(n, g.case + 1, 30.0);
        let targets = random_cloud(m, g.case + 2, 30.0);
        let tree = KdTree::build(&targets);
        let cfg = nn::KernelConfig {
            block_n: 64,
            block_m: 128,
        };
        let (ps, _) = nn::pad_cloud(&queries.xyz, cfg.block_n);
        let (pt, mask) = nn::pad_cloud(&targets.xyz, cfg.block_m);
        let mirror = nn::kernel_mirror(&ps, &pt, &mask, cfg);
        for (i, q) in queries.iter().enumerate() {
            let kd = tree.nearest(q).unwrap();
            let brute = nn::nearest_brute(&targets, q).unwrap();
            assert_eq!(kd.dist_sq, brute.1, "kd vs brute case {}", g.case);
            // Mirror uses the identity distance form: compare through
            // the chosen point, not the raw value.
            let t = targets.get(mirror.index[i] as usize);
            let chosen = nn::dist_sq(q, t);
            assert!(
                chosen <= kd.dist_sq + 1e-3,
                "mirror suboptimal: case {} i={i} {chosen} vs {}",
                g.case,
                kd.dist_sq
            );
        }
    });
}

// ---------- ICP invariants ----------

#[test]
fn icp_transform_is_always_rigid() {
    forall(default_cases(10), |g| {
        let target = random_cloud(400, g.case + 50, 8.0);
        let motion = Mat4::from_rt(
            g.rotation(0.08),
            Vec3::new(
                g.f32_range(-0.3, 0.3) as f64,
                g.f32_range(-0.3, 0.3) as f64,
                0.0,
            ),
        );
        let source = target.transformed(&motion.inverse_rigid());
        let res = fpps::icp::align(&source, &target, &Mat4::IDENTITY, &IcpParams::default());
        // Whatever happened, the output must be a rigid transform.
        assert!(
            res.transformation.rotation().is_rotation(1e-6),
            "non-rigid output, case {}",
            g.case
        );
    });
}

#[test]
fn icp_epsilon_semantics() {
    // Tighter epsilon can only require >= iterations than a looser one.
    let target = random_cloud(600, 7, 6.0);
    let motion = Mat4::from_rt(Mat3::rot_z(0.03), Vec3::new(0.2, -0.1, 0.0));
    let source = target.transformed(&motion.inverse_rigid());
    let run = |eps: f64| {
        fpps::icp::align(
            &source,
            &target,
            &Mat4::IDENTITY,
            &IcpParams {
                transformation_epsilon: eps,
                ..Default::default()
            },
        )
        .iterations
    };
    let loose = run(1e-2);
    let tight = run(1e-7);
    assert!(tight >= loose, "tight {tight} < loose {loose}");
}

#[test]
fn icp_brute_and_kdtree_identical_result() {
    let target = random_cloud(500, 11, 7.0);
    let motion = Mat4::from_rt(Mat3::rot_z(-0.04), Vec3::new(0.15, 0.2, 0.01));
    let source = target.transformed(&motion.inverse_rigid());
    let a = fpps::icp::align(&source, &target, &Mat4::IDENTITY, &IcpParams::default());
    let b = fpps::icp::align(
        &source,
        &target,
        &Mat4::IDENTITY,
        &IcpParams {
            search: SearchStrategy::Brute,
            ..Default::default()
        },
    );
    // Exact same correspondences → same transforms bit-for-bit-ish.
    assert!(
        (a.transformation.translation() - b.transformation.translation()).norm() < 1e-9
    );
    assert!((a.rmse - b.rmse).abs() < 1e-9);
}

// ---------- FPPS API vs CPU baseline (backend-free Table III) ----------

#[test]
fn fpps_and_cpu_agree_on_shared_clouds() {
    forall(default_cases(5), |g| {
        let target = random_cloud(700, g.case + 90, 8.0);
        let motion = Mat4::from_rt(g.rotation(0.05), Vec3::new(0.2, 0.1, 0.0));
        let mut source = target.transformed(&motion.inverse_rigid());
        source.add_noise(0.005, g.rng());

        let cpu = fpps::icp::align(&source, &target, &Mat4::IDENTITY, &IcpParams::default());
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source).set_input_target(target);
        let dev = icp.align().unwrap();
        assert!(
            (cpu.rmse - dev.rmse).abs() < 0.01,
            "Table III margin: {} vs {} case {}",
            cpu.rmse,
            dev.rmse,
            g.case
        );
    });
}

// ---------- Kabsch noise robustness ----------

#[test]
fn kabsch_degrades_gracefully_with_noise() {
    forall(default_cases(20), |g| {
        let n = g.usize_range(10, 100);
        let r = g.rotation(1.0);
        let t = Vec3::from_f32(g.point(3.0));
        let ps: Vec<Vec3> = g.points(n, 4.0).into_iter().map(Vec3::from_f32).collect();
        let sigma = 0.01;
        let qs: Vec<Vec3> = ps
            .iter()
            .map(|&p| r.mul_vec(p) + t + Vec3::from_f32(g.point(sigma)))
            .collect();
        let est = kabsch_from_pairs(&ps, &qs).expect("estimate");
        // Rotation error bounded by noise/scale ratio (loose bound).
        let err = est.rotation.rotation_angle_to(&r);
        assert!(err < 0.1, "rotation error {err} with {sigma} noise");
    });
}

// ---------- dataset + io round trip ----------

#[test]
fn kitti_dir_roundtrip_through_sequence_loader() {
    // Write a synthetic sequence in the on-disk KITTI layout, reload it
    // via Sequence::from_kitti_dir, verify frames and poses survive.
    let tmp = std::env::temp_dir().join(format!("fpps_kitti_{}", std::process::id()));
    let velo = tmp.join("velodyne");
    std::fs::create_dir_all(&velo).unwrap();

    let spec = sequence_specs()[4].clone();
    let gen = Sequence::synthetic(spec.clone(), 3, 5, LidarConfig::tiny());
    for i in 0..gen.len() {
        let cloud = gen.frame(i).unwrap();
        io::write_kitti_bin(&cloud, &velo.join(format!("{i:06}.bin"))).unwrap();
    }
    io::write_kitti_poses(&gen.ground_truth, &tmp.join("poses.txt")).unwrap();

    let loaded = Sequence::from_kitti_dir(spec, &tmp, 100).unwrap();
    assert_eq!(loaded.len(), 3);
    for i in 0..3 {
        assert_eq!(loaded.frame(i).unwrap(), gen.frame(i).unwrap());
        let dp = (loaded.ground_truth[i].translation() - gen.ground_truth[i].translation())
            .norm();
        assert!(dp < 1e-9);
    }
    std::fs::remove_dir_all(&tmp).ok();
}

// ---------- coordinator front end ----------

#[test]
fn preprocess_filters_are_sound() {
    let cfg = PipelineConfig {
        voxel_leaf: 0.0, // test crop/ground in isolation
        ..Default::default()
    };
    let mut cloud = PointCloud::new();
    cloud.push([1.0, 0.0, 0.0]); // keep
    cloud.push([100.0, 0.0, 0.0]); // beyond crop_range 40
    cloud.push([1.0, 0.0, -1.5]); // below ground_z_min -1.2
    cloud.push([5.0, 5.0, 1.0]); // keep
    let out = preprocess(&cloud, &cfg);
    assert_eq!(out.len(), 2);
    // Raw config keeps everything.
    let raw = preprocess(&cloud, &PipelineConfig::raw());
    assert_eq!(raw.len(), 4);
}

#[test]
fn preprocess_voxel_bounds_density() {
    let cloud = random_cloud(5000, 3, 20.0);
    let cfg = PipelineConfig {
        crop_range: 0.0,
        ground_z_min: f32::NEG_INFINITY,
        voxel_leaf: 0.5,
        ..Default::default()
    };
    let out = preprocess(&cloud, &cfg);
    assert!(out.len() < cloud.len());
    // No two output points share a voxel.
    let mut seen = std::collections::HashSet::new();
    for p in out.iter() {
        let key = (
            (p[0] / 0.5).floor() as i32,
            (p[1] / 0.5).floor() as i32,
            (p[2] / 0.5).floor() as i32,
        );
        assert!(seen.insert(key), "two centroids in one voxel");
    }
}

// ---------- residency coordinator vs real backend residency ----------

#[test]
fn router_mirror_is_always_a_subset_of_backend_residency() {
    // Drive the pool residency coordinator against one real
    // NativeSimBackend per lane, mimicking exactly what a lane worker
    // does per job (activate → hit, else upload; poisoned jobs fail
    // before touching residency) and feeding the completion back. After
    // every completion, each lane's mirrored warm set must be a subset
    // of its backend's `resident_epochs()` keys — the mirror may forget
    // warmth (conservative, costs a re-upload) but must never claim
    // warmth the device does not have.
    forall(default_cases(25), |g| {
        let lanes = g.usize_range(1, 3);
        let slots = g.usize_range(1, 3);
        let distinct_keys = g.usize_range(1, 6) as u64;
        let mut router = AffinityRouter::new(lanes, slots);
        let mut backends: Vec<NativeSimBackend> = (0..lanes)
            .map(|_| NativeSimBackend::with_residency_slots(slots))
            .collect();
        let tgt = vec![0.5f32; 4 * 3];
        let mask = vec![1f32; 4];
        for step in 0..40 {
            let key = 1 + g.usize_range(0, distinct_keys as usize - 1) as u64;
            let poisoned = g.usize_range(0, 4) == 0;
            // A job can also fail *after* touching residency (bad
            // source, step error): the upload/hit still happened.
            let late_failure = g.usize_range(0, 5) == 0;
            // Route exactly like the channel loop (queues never fill in
            // this synchronous harness).
            let lane = router
                .first_choice(key)
                .unwrap_or_else(|| router.spill_order(None)[0]);
            router.committed(lane, key);
            let (uploaded, hit) = if poisoned {
                (false, false) // failed before the target upload
            } else if backends[lane].activate_target(key).is_some() {
                (false, true) // cache hit
            } else {
                backends[lane].upload_target_keyed(key, &tgt, &mask).unwrap();
                (true, false)
            };
            router.completed(JobFeedback {
                lane,
                key,
                uploaded,
                hit,
                ok: !poisoned && !late_failure,
                generation: 0,
            });
            for (l, backend) in backends.iter().enumerate() {
                let resident: Vec<u64> =
                    backend.resident_epochs().iter().map(|(k, _)| *k).collect();
                for &w in router.warm_keys(l) {
                    assert!(
                        resident.contains(&w),
                        "case {} step {step}: lane {l} mirror claims key {w:#x} \
                         but backend holds {resident:?}",
                        g.case
                    );
                }
            }
        }
    });
}

// ---------- NativeSim begin/step protocol ----------

#[test]
fn backend_step_without_begin_errors() {
    let mut b = NativeSimBackend::new();
    assert!(b.step(&Mat4::IDENTITY, 1.0).is_err());
}

#[test]
fn backend_steps_are_repeatable_after_one_begin() {
    let mut b = NativeSimBackend::with_blocks(64, 128);
    let src = vec![0.5f32; 64 * 3];
    let tgt = vec![0.25f32; 128 * 3];
    let smask = vec![1f32; 64];
    let tmask = vec![1f32; 128];
    b.begin(&src, &tgt, &smask, &tmask).unwrap();
    let a = b.step(&Mat4::IDENTITY, 1e30).unwrap();
    let c = b.step(&Mat4::IDENTITY, 1e30).unwrap();
    assert_eq!(a.count, c.count);
    assert_eq!(a.sum_sq_dist, c.sum_sq_dist);
}

// ---------- hwmodel monotonicity ----------

#[test]
fn hwmodel_monotonicity_properties() {
    use fpps::hwmodel::{latency, AcceleratorConfig};
    forall(default_cases(25), |g| {
        let cfg = AcceleratorConfig::default();
        let n1 = g.usize_range(64, 4096);
        let m1 = g.usize_range(1024, 131_072);
        let n2 = n1 * 2;
        let m2 = m1 * 2;
        // Cycles monotone in both workload dimensions.
        assert!(
            latency::nn_search_cycles(&cfg, n2, m1) > latency::nn_search_cycles(&cfg, n1, m1)
        );
        assert!(
            latency::nn_search_cycles(&cfg, n1, m2) > latency::nn_search_cycles(&cfg, n1, m1)
        );
        // Frame latency monotone in iterations.
        let a = latency::frame_latency(&cfg, n1, m1, 5).total_s;
        let b = latency::frame_latency(&cfg, n1, m1, 6).total_s;
        assert!(b > a);
    });
}

// ---------- §V: approximate kd-tree degrades ICP convergence ----------

#[test]
fn section5_approximate_search_degrades_icp() {
    // The paper's §V claim: "Approximate k-d tree search can reduce
    // computational complexity but often leads to degraded convergence
    // in ICP due to inaccurate correspondences."
    let target = random_cloud(1500, 77, 8.0);
    let motion = Mat4::from_rt(Mat3::rot_z(0.06), Vec3::new(0.35, -0.2, 0.02));
    let source = target.transformed(&motion.inverse_rigid());

    let run = |search: SearchStrategy| {
        fpps::icp::align(
            &source,
            &target,
            &Mat4::IDENTITY,
            &IcpParams {
                search,
                ..Default::default()
            },
        )
    };
    let exact = run(SearchStrategy::KdTree);
    let greedy = run(SearchStrategy::KdTreeApproximate { max_leaf_visits: 1 });

    let err = |r: &fpps::icp::IcpResult| {
        (r.transformation.translation() - motion.translation()).norm()
    };
    // Exact search recovers the motion precisely…
    assert!(err(&exact) < 0.02, "exact err {}", err(&exact));
    // …and the greedy-descent approximation is measurably worse (either
    // final accuracy or convergence quality).
    let degraded = err(&greedy) > 2.0 * err(&exact) + 1e-4
        || greedy.rmse > 2.0 * exact.rmse + 1e-4
        || greedy.iterations > exact.iterations;
    assert!(
        degraded,
        "approximate search unexpectedly matched exact: err {} vs {}, rmse {} vs {}, it {} vs {}",
        err(&greedy),
        err(&exact),
        greedy.rmse,
        exact.rmse,
        greedy.iterations,
        exact.iterations
    );
}
