//! Allocation-regression suite for the zero-copy data plane: once an
//! engine (or a ring, or the buffer pool) is warm, the per-job hot path
//! must perform **zero** heap allocations. A counting global allocator
//! ([`fpps::alloc_counter::CountingAlloc`]) is installed for this test
//! binary only; every measurement takes the process-wide `GATE` lock so
//! concurrently scheduled tests cannot pollute the counters.

use fpps::alloc_counter::{snapshot, CountingAlloc};
use fpps::fpps_api::{FppsIcp, KdTreeCpuBackend, KernelBackend};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::pool::ring::SpscRing;
use fpps::pool::BufferPool;
use fpps::rng::Pcg32;
use fpps::voxelgrid::NnStrategy;
use std::sync::{Arc, Mutex};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Serializes the measured regions (the counters are process-global).
static GATE: Mutex<()> = Mutex::new(());

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

fn workload() -> (Arc<PointCloud>, Arc<PointCloud>) {
    let target = Arc::new(structured_cloud(600, 1));
    let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, -0.05, 0.0));
    let source = Arc::new(target.transformed(&gt.inverse_rigid()));
    (source, target)
}

/// Warm the engine, then assert 20 further jobs allocate nothing: the
/// pooled staging, the backend mirrors, and the recycled iteration-stat
/// buffer must absorb every byte of per-job traffic.
fn assert_steady_state_is_allocation_free<B: KernelBackend>(mut icp: FppsIcp<B>, label: &str) {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let (source, target) = workload();
    let mut align = |icp: &mut FppsIcp<B>| {
        icp.set_input_source(Arc::clone(&source));
        icp.set_input_target(Arc::clone(&target));
        let mut res = icp.align().expect("align");
        assert!(res.rmse.is_finite(), "{label}: alignment degenerated");
        icp.recycle_stats(std::mem::take(&mut res.stats));
    };
    for _ in 0..3 {
        align(&mut icp);
    }
    let before = snapshot();
    for _ in 0..20 {
        align(&mut icp);
    }
    let delta = before.delta(&snapshot());
    assert_eq!(
        delta.allocations, 0,
        "{label}: steady-state align must not allocate \
         (saw {} allocations / {} bytes across 20 jobs)",
        delta.allocations, delta.bytes
    );
}

#[test]
fn native_sim_steady_state_alignment_is_allocation_free() {
    assert_steady_state_is_allocation_free(FppsIcp::native_sim(), "native-sim");
}

#[test]
fn kdtree_steady_state_alignment_is_allocation_free() {
    assert_steady_state_is_allocation_free(FppsIcp::kdtree_cpu(), "kdtree-cpu");
}

#[test]
fn kdtree_with_voxel_grid_steady_state_is_allocation_free() {
    // The voxel-grid NN path must keep the warm-path guarantee: the grid
    // is built once at upload (cached by the residency slot alongside the
    // kd-tree), and its ring-scan queries plus the chunked query loop and
    // cancellation checks are pure reads. tests/nn_strategy.rs proves
    // this exact strategy routes queries through the grid.
    let mut b = KdTreeCpuBackend::new();
    b.set_nn_strategy(NnStrategy::Approx {
        cell_size: 1.0,
        max_ring: 2,
    });
    assert_steady_state_is_allocation_free(FppsIcp::with_backend(b), "kdtree-cpu+grid");
}

#[test]
fn spsc_ring_hot_ops_are_allocation_free() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let ring: SpscRing<u64> = SpscRing::new(8);
    // Warm one lap so every slot has been written once.
    for i in 0..8 {
        ring.try_push(i).unwrap();
    }
    while ring.try_pop().is_some() {}
    let before = snapshot();
    for i in 0..10_000u64 {
        ring.try_push(i).unwrap();
        assert_eq!(ring.try_pop(), Some(i));
    }
    assert!(ring.drain().is_empty(), "empty drain stays empty");
    let delta = before.delta(&snapshot());
    assert_eq!(
        delta.allocations, 0,
        "ring push/pop/empty-drain must not allocate \
         (saw {} allocations / {} bytes)",
        delta.allocations, delta.bytes
    );
}

#[test]
fn buffer_pool_steady_state_is_allocation_free() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let pool = BufferPool::default();
    // Warm the capacity classes (first acquire per class allocates).
    for cap in [256usize, 1024, 4096] {
        drop(pool.acquire(cap));
    }
    let before = snapshot();
    for _ in 0..1000 {
        for cap in [256usize, 1024, 4096] {
            let buf = pool.acquire(cap);
            assert!(buf.capacity() >= cap);
            drop(buf); // recycles back onto the shelf
        }
    }
    let delta = before.delta(&snapshot());
    assert_eq!(
        delta.allocations, 0,
        "warm pool acquire/recycle must not allocate \
         (saw {} allocations / {} bytes)",
        delta.allocations, delta.bytes
    );
    let stats = pool.stats();
    assert_eq!(stats.grows, 3, "one growth per capacity class");
    assert_eq!(stats.recycles, 3000, "every warm acquire recycled");
}
