//! Model-checked interleaving tests for the lock-free data plane.
//!
//! These tests only compile under `RUSTFLAGS="--cfg loom"`, which
//! switches [`fpps::sync`] from `std::sync` re-exports to the in-repo
//! model checker ([`fpps::sync::model`]): every execution below runs
//! under a deterministic scheduler that explores interleavings via
//! bounded DFS, detects data races with vector clocks, and panics on
//! deadlock or missed wakeups (a waiter that nothing can wake is a
//! deadlock by definition). Run them with:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test -q --test loom_models
//! ```
//!
//! Each test asserts the property *inside* the model closure — so it is
//! checked on every explored schedule — and asserts afterwards that the
//! search explored more than one schedule (i.e. the model actually had
//! concurrency to check).
#![cfg(loom)]

use fpps::coordinator::claim::ClaimSlot;
use fpps::coordinator::completion::CompletionCell;
use fpps::pool::ring::SpscRing;
use fpps::pool::BufferPool;
use fpps::sync::atomic::{AtomicUsize, Ordering};
use fpps::sync::model::{model, thread};
use std::sync::Arc;
use std::time::Duration;

/// Producer push vs blocking consumer pop vs watchdog drain: every job
/// pushed into the ring is observed by exactly one consumer, on every
/// interleaving — the tail-CAS claim protocol is exactly-once.
#[test]
fn ring_jobs_are_consumed_exactly_once() {
    let schedules = model(|| {
        let r = Arc::new(SpscRing::new(2));
        let worker = {
            let r = Arc::clone(&r);
            thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = r.pop() {
                    got.push(v);
                }
                got
            })
        };
        let watchdog = {
            let r = Arc::clone(&r);
            thread::spawn(move || r.drain())
        };
        assert!(r.try_push(1u32).is_ok(), "capacity-2 ring takes job 1");
        assert!(r.try_push(2u32).is_ok(), "capacity-2 ring takes job 2");
        r.close();
        let mut all = worker.join().unwrap();
        all.extend(watchdog.join().unwrap());
        all.extend(r.drain());
        all.sort_unstable();
        assert_eq!(all, vec![1, 2], "no job lost, none seen twice");
    });
    assert!(schedules > 1, "expected real interleavings, got {schedules}");
}

/// Close + drain racing an in-flight push: the job either bounces back
/// to the producer (who re-routes it) or lands in the ring, where the
/// producer's authoritative final drain finds it — never silently lost.
#[test]
fn ring_close_drain_race_loses_no_job() {
    let schedules = model(|| {
        let r = Arc::new(SpscRing::new(2));
        let closer = {
            let r = Arc::clone(&r);
            thread::spawn(move || {
                r.close();
                r.drain()
            })
        };
        let accepted = r.try_push(7u32).is_ok();
        let mut drained = closer.join().unwrap();
        // The dispatcher is the sole producer: after it learns of the
        // close it performs the authoritative final drain itself.
        drained.extend(r.drain());
        if accepted {
            assert_eq!(drained, vec![7], "accepted job must surface in a drain");
        } else {
            assert!(drained.is_empty(), "refused push leaves nothing behind");
        }
    });
    assert!(schedules > 1, "expected real interleavings, got {schedules}");
}

/// Completion-set vs `set_waker` vs `wait_timeout`: the waiter always
/// receives the outcome (no missed wakeup — a lost notify would
/// deadlock the model) and the waker fires exactly once, whether it was
/// registered before or after the completion landed.
#[test]
fn completion_never_misses_a_wakeup_and_wakes_once() {
    let schedules = model(|| {
        let cell = Arc::new(CompletionCell::new());
        let fired = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let c = Arc::clone(&cell);
            thread::spawn(move || c.wait_timeout(Duration::from_secs(3600)))
        };
        let registrar = {
            let c = Arc::clone(&cell);
            let fired = Arc::clone(&fired);
            thread::spawn(move || {
                c.set_waker(move || {
                    // ordering: Relaxed — exactly-once counter asserted
                    // after both threads join; no data published through it.
                    fired.fetch_add(1, Ordering::Relaxed);
                })
            })
        };
        cell.complete(9u32);
        assert_eq!(waiter.join().unwrap(), Some(9), "waiter sees the outcome");
        registrar.join().unwrap();
        // ordering: Relaxed — both writers joined above.
        assert_eq!(fired.load(Ordering::Relaxed), 1, "waker fires exactly once");
    });
    assert!(schedules > 1, "expected real interleavings, got {schedules}");
}

/// Two threads acquiring and returning pool buffers concurrently: the
/// stats ledger stays consistent (every acquire is a grow or a recycle)
/// and nothing is discarded while the shelf has room.
#[test]
fn pool_acquire_recycle_ledger_is_consistent() {
    let schedules = model(|| {
        let pool = BufferPool::new(4);
        let clone = pool.clone();
        let t = thread::spawn(move || {
            let mut b = clone.acquire(64);
            b.push(1.0);
        });
        {
            let mut b = pool.acquire(64);
            b.push(2.0);
        }
        t.join().unwrap();
        let s = pool.stats();
        assert_eq!(s.acquires, 2);
        assert_eq!(s.grows + s.recycles, 2, "every acquire grows or recycles");
        assert!(s.grows >= 1, "first acquire must allocate");
        assert_eq!(s.discards, 0, "shelf has room; returns must be kept");
    });
    assert!(schedules > 1, "expected real interleavings, got {schedules}");
}

/// Lane publish/finish racing the watchdog's claim: exactly one side
/// owns the job's resolution, and the slot always accepts the next
/// attempt after the recovery path runs.
#[test]
fn claim_slot_resolves_every_job_exactly_once() {
    let schedules = model(|| {
        let slot = Arc::new(ClaimSlot::new());
        let watchdog = {
            let slot = Arc::clone(&slot);
            thread::spawn(move || slot.try_claim(|_| true))
        };
        assert!(slot.publish_with(5u32, || {}));
        let deferred = slot.finish();
        let claimed = watchdog.join().unwrap();
        assert_eq!(
            claimed.is_some(),
            deferred,
            "exactly one of lane/watchdog owns the resolution"
        );
        if deferred {
            assert_eq!(claimed, Some(5));
            slot.clear(); // recovery path for a claimed job
        }
        assert!(slot.publish_with(6u32, || {}), "slot accepts the next attempt");
        assert!(!slot.finish(), "unclaimed follow-up resolves on the lane");
    });
    assert!(schedules > 1, "expected real interleavings, got {schedules}");
}
