//! Long randomized stress for the lock-free data plane, `#[ignore]`d by
//! default: the nightly ThreadSanitizer job runs it with
//! `--include-ignored`, and it can be run locally with
//!
//!   cargo test -q --test stress -- --include-ignored
//!   FPPS_STRESS_SEED=7 cargo test -q --test stress -- --include-ignored
//!
//! These are schedule-shotgun companions to the exhaustive (but tiny)
//! loom models in `tests/loom_models.rs`: the same exactly-once and
//! lost-wakeup invariants, checked at scale under real OS scheduling
//! with a seeded random mix of operations.

use fpps::coordinator::{
    LaneIcpConfig, RegistrationJob, ServingConfig, ServingPool, SloClass, Submission,
    SupervisorConfig,
};
use fpps::fpps_api::NativeSimBackend;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::pool::ring::SpscRing;
use fpps::rng::Pcg32;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

fn seed() -> u64 {
    std::env::var("FPPS_STRESS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF1F5)
}

/// Producer, consumer, and a drain-happy watchdog churn one SPSC ring;
/// every pushed item must surface exactly once across the consumer's
/// pops, the watchdog's drains, and the final sweep.
#[test]
#[ignore = "long randomized stress; nightly TSan job runs it with --include-ignored"]
fn ring_randomized_push_pop_drain_is_exactly_once() {
    const ITEMS: u64 = 100_000;
    let ring: Arc<SpscRing<u64>> = Arc::new(SpscRing::new(64));

    let producer = {
        let ring = Arc::clone(&ring);
        let mut rng = Pcg32::new(seed());
        thread::spawn(move || {
            for i in 0..ITEMS {
                let mut v = i;
                loop {
                    match ring.try_push(v) {
                        Ok(()) => break,
                        Err(back) => {
                            v = back;
                            thread::yield_now();
                        }
                    }
                }
                if rng.below(64) == 0 {
                    thread::yield_now();
                }
            }
            ring.close();
        })
    };

    let consumer = {
        let ring = Arc::clone(&ring);
        let mut rng = Pcg32::new(seed() ^ 0x5EED);
        thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = ring.pop() {
                got.push(v);
                if rng.below(128) == 0 {
                    thread::yield_now();
                }
            }
            got
        })
    };

    let watchdog = {
        let ring = Arc::clone(&ring);
        let mut rng = Pcg32::new(seed() ^ 0xD06);
        thread::spawn(move || {
            let mut got = Vec::new();
            while !ring.is_closed() {
                if rng.below(4) == 0 {
                    got.extend(ring.drain());
                }
                thread::yield_now();
            }
            got
        })
    };

    producer.join().expect("producer");
    let mut all = consumer.join().expect("consumer");
    all.extend(watchdog.join().expect("watchdog"));
    all.extend(ring.drain());
    all.sort_unstable();
    let expect: Vec<u64> = (0..ITEMS).collect();
    assert_eq!(all, expect, "every item exactly once, none lost or duplicated");
}

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

fn stress_job(id: u64, class: SloClass) -> RegistrationJob {
    let target = structured_cloud(300, 100 + id);
    let gt = Mat4::from_rt(
        Mat3::rot_z(0.01 * (id as f64 % 7.0 + 1.0)),
        Vec3::new(0.1, -0.05, 0.01),
    );
    let source = target.transformed(&gt.inverse_rigid());
    RegistrationJob::new(id, id as usize % 3, source, target, Mat4::IDENTITY).with_slo(class)
}

/// A storm of client threads with random SLO classes and a random mix
/// of completion styles (blocking wait, timeout polling, waker +
/// channel); every admitted or shed job must resolve exactly once with
/// its own id.
#[test]
#[ignore = "long randomized stress; nightly TSan job runs it with --include-ignored"]
fn serving_randomized_submission_storm_resolves_every_job() {
    const CLIENTS: u64 = 4;
    const JOBS_PER_CLIENT: u64 = 16;
    let pool = ServingPool::start(
        2,
        2,
        LaneIcpConfig::default(),
        SupervisorConfig::default(),
        ServingConfig::default(),
        |_lane, _tier| Ok(NativeSimBackend::new()),
    )
    .expect("pool start");

    let mut workers = Vec::new();
    for t in 0..CLIENTS {
        let client = pool.client();
        workers.push(thread::spawn(move || {
            let mut rng = Pcg32::substream(seed(), t);
            let mut resolved = 0u64;
            for k in 0..JOBS_PER_CLIENT {
                let id = t * 1000 + k;
                let class = match rng.below(3) {
                    0 => SloClass::Standard,
                    1 => SloClass::BestEffort,
                    _ => SloClass::LatencyCritical,
                };
                let mut job = stress_job(id, class);
                let handle = loop {
                    match client.try_submit(job).expect("pool alive") {
                        Submission::Accepted(h) | Submission::Shed(h) => break h,
                        Submission::Parked(back) => {
                            job = back;
                            thread::yield_now();
                        }
                    }
                };
                assert_eq!(handle.id(), id);
                let outcome = match rng.below(3) {
                    0 => handle.wait(),
                    1 => loop {
                        if let Some(o) = handle.wait_timeout(Duration::from_millis(50)) {
                            break o;
                        }
                    },
                    _ => {
                        let (tx, rx) = mpsc::channel();
                        handle.set_waker(move || {
                            tx.send(()).ok();
                        });
                        rx.recv().expect("waker fires");
                        handle.try_take().expect("complete after waker")
                    }
                };
                assert_eq!(outcome.id, id, "outcome routed to the submitting handle");
                resolved += 1;
            }
            resolved
        }));
    }

    let total: u64 = workers.into_iter().map(|w| w.join().expect("client")).sum();
    assert_eq!(total, CLIENTS * JOBS_PER_CLIENT, "every job resolved exactly once");
    pool.shutdown().expect("clean shutdown");
}
