//! Chaos tests for the supervised lane pool: deterministic fault plans
//! (transient errors, wedged uploads, NaN-corrupted transforms, lane
//! panics) injected via [`FaultInjectingBackend`], with the supervision
//! layer expected to contain every one of them — no deadlock, no lost
//! or duplicated jobs, unfaulted results bit-identical to a clean
//! sequential run, and hangs cut off by the deadline watchdog.

use std::time::{Duration, Instant};

use fpps::coordinator::{
    run_registration_batch, run_registration_batch_supervised, LaneIcpConfig, LaneReport,
    RegistrationJob, RegistrationOutcome, SupervisorConfig,
};
use fpps::fault::{FaultInjectingBackend, FaultKind, FaultPlan};
use fpps::fpps_api::KdTreeCpuBackend;
use fpps::icp::StopReason;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

/// Independent seeded frame-pair jobs spread over three logical streams.
fn synthetic_jobs(n: usize) -> Vec<RegistrationJob> {
    (0..n)
        .map(|k| {
            let target = structured_cloud(600, 100 + k as u64);
            let gt = Mat4::from_rt(
                Mat3::rot_z(0.01 * (k as f64 + 1.0)),
                Vec3::new(0.1 + 0.02 * k as f64, -0.05, 0.01),
            );
            let source = target.transformed(&gt.inverse_rigid());
            RegistrationJob::new(k as u64, k % 3, source, target, Mat4::IDENTITY)
        })
        .collect()
}

/// Clean single-lane reference run — the bit-identity baseline every
/// recovered job must match (retries restart the whole alignment, so a
/// successful attempt carries no trace of the faults before it).
fn clean_baseline(n: usize) -> LaneReport {
    run_registration_batch(synthetic_jobs(n), 1, 2, LaneIcpConfig::default(), |_| {
        Ok(KdTreeCpuBackend::new())
    })
    .unwrap()
}

fn assert_bit_identical(a: &RegistrationOutcome, b: &RegistrationOutcome) {
    assert_eq!(a.id, b.id);
    assert_eq!(a.transform.m, b.transform.m, "job {} transform", a.id);
    assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {} rmse", a.id);
    assert_eq!(a.iterations, b.iterations, "job {} iterations", a.id);
}

/// Every submitted id must come back exactly once — faults may fail a
/// job, never lose or duplicate it.
fn assert_exactly_once(report: &LaneReport, n: usize) {
    let mut ids: Vec<u64> = report.outcomes.iter().map(|o| o.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "job accounting");
}

#[test]
fn transient_errors_are_retried_to_bit_identical_results() {
    let n = 6;
    let baseline = clean_baseline(n);
    // Single lane, so align-attempt ordinals are deterministic: the
    // faults hit job 0's first attempt and job 2's first attempt.
    let plan = FaultPlan::scripted([
        (0, FaultKind::TransientError),
        (3, FaultKind::TransientError),
    ]);
    let sup = SupervisorConfig {
        max_retries: 2,
        ..Default::default()
    };
    let report = run_registration_batch_supervised(
        synthetic_jobs(n),
        1,
        2,
        LaneIcpConfig::default(),
        sup,
        move |_lane, _tier| Ok(FaultInjectingBackend::new(KdTreeCpuBackend::new(), plan.clone())),
    )
    .unwrap();

    assert_exactly_once(&report, n);
    for (a, b) in report.outcomes.iter().zip(baseline.outcomes.iter()) {
        assert!(!a.is_failed(), "job {} must recover: {:?}", a.id, a.error);
        assert_bit_identical(a, b);
    }
    assert!(report.outcomes.iter().any(|o| o.attempts >= 2));
    assert!(report.lanes[0].retries >= 1, "retries must be accounted");
}

#[test]
fn panicking_lane_is_respawned_and_failover_escalates() {
    let n = 5;
    let baseline = clean_baseline(n);
    // Tier 0 panics on its first align attempt; one restart advances
    // the lane to tier 1 where the chain hands out a clean backend.
    let plan = FaultPlan::scripted([(0, FaultKind::Panic)]);
    let sup = SupervisorConfig {
        max_retries: 2,
        restarts_per_tier: 1,
        ..Default::default()
    };
    let report = run_registration_batch_supervised(
        synthetic_jobs(n),
        1,
        2,
        LaneIcpConfig::default(),
        sup,
        move |_lane, tier| {
            let p = if tier == 0 { plan.clone() } else { FaultPlan::none() };
            Ok(FaultInjectingBackend::new(KdTreeCpuBackend::new(), p))
        },
    )
    .unwrap();

    assert_exactly_once(&report, n);
    for (a, b) in report.outcomes.iter().zip(baseline.outcomes.iter()) {
        assert!(!a.is_failed(), "job {} must recover: {:?}", a.id, a.error);
        assert_bit_identical(a, b);
    }
    assert!(report.lanes[0].restarts >= 1, "panic must respawn the lane");
    assert_eq!(report.lanes[0].backend_tier, 1, "failover must escalate");
    assert!(report.outcomes[0].attempts >= 2);
}

#[test]
fn wedged_lane_is_cut_off_by_the_watchdog() {
    let n = 8;
    let baseline = clean_baseline(n);
    // Lane 0 wedges for 60 s on its first align attempt; the watchdog
    // must claim the job at its ~400 ms deadline and cancel the stall.
    // Jobs queued behind the wedge may legitimately miss their own
    // deadlines too, so the assertions are about containment, not about
    // which specific jobs survive.
    let stall = FaultPlan::scripted([(0, FaultKind::StallMs(60_000))]);
    let sup = SupervisorConfig {
        deadline: Some(Duration::from_millis(400)),
        ..Default::default()
    };
    let start = Instant::now();
    let report = run_registration_batch_supervised(
        synthetic_jobs(n),
        2,
        2,
        LaneIcpConfig::default(),
        sup,
        move |lane, _tier| {
            let p = if lane == 0 { stall.clone() } else { FaultPlan::none() };
            Ok(FaultInjectingBackend::new(KdTreeCpuBackend::new(), p))
        },
    )
    .unwrap();
    let elapsed = start.elapsed();

    assert!(
        elapsed < Duration::from_secs(30),
        "watchdog must cut the 60 s stall off, ran {elapsed:?}"
    );
    assert_exactly_once(&report, n);
    let missed: Vec<&RegistrationOutcome> = report
        .outcomes
        .iter()
        .filter(|o| o.stop == StopReason::DeadlineExceeded)
        .collect();
    assert!(!missed.is_empty(), "the wedged job must miss its deadline");
    assert!(
        missed.iter().all(|o| o.is_failed() && o.rmse.is_nan()),
        "deadline outcomes are contained failures"
    );
    assert!(
        missed
            .iter()
            .any(|o| o.error.as_deref().unwrap_or("").contains("watchdog")),
        "at least the wedged job is claimed by the watchdog"
    );
    let deadline_missed: usize = report.lanes.iter().map(|l| l.deadline_missed).sum();
    assert!(deadline_missed >= missed.len());
    for o in report.outcomes.iter().filter(|o| !o.is_failed()) {
        assert_bit_identical(o, &baseline.outcomes[o.id as usize]);
    }
}

#[test]
fn deadline_expired_job_on_a_large_map_stops_between_chunks() {
    // City-scale containment, no injected stall needed: the map is big
    // enough that the alignment alone blows the deadline. The watchdog
    // raises the lane's cancellation token, the chunked NN loop checks
    // it between fixed-size query blocks and bails mid-step, and the
    // job surfaces as a contained DeadlineExceeded instead of running
    // the full scan to completion.
    let target = structured_cloud(120_000, 901);
    let source = structured_cloud(50_000, 902);
    let jobs = vec![RegistrationJob::new(0, 0, source, target, Mat4::IDENTITY)];
    let sup = SupervisorConfig {
        deadline: Some(Duration::from_millis(250)),
        ..Default::default()
    };
    let start = Instant::now();
    let report = run_registration_batch_supervised(
        jobs,
        1,
        2,
        LaneIcpConfig::default(),
        sup,
        |_lane, _tier| Ok(KdTreeCpuBackend::new()),
    )
    .unwrap();
    let elapsed = start.elapsed();

    // 50k queries × 50 iterations against a 120k-point map would take
    // far longer than this bound if the deadline were ignored.
    assert!(
        elapsed < Duration::from_secs(60),
        "deadline containment must cut the scan short, ran {elapsed:?}"
    );
    assert_exactly_once(&report, 1);
    let o = &report.outcomes[0];
    assert_eq!(
        o.stop,
        StopReason::DeadlineExceeded,
        "oversized job must surface the deadline, got {:?} ({:?})",
        o.stop,
        o.error
    );
    assert!(o.is_failed() && o.rmse.is_nan(), "contained failure");
    assert!(
        o.error.as_deref().unwrap_or("").contains("deadline"),
        "the error names the deadline: {:?}",
        o.error
    );
    let deadline_missed: usize = report.lanes.iter().map(|l| l.deadline_missed).sum();
    assert!(deadline_missed >= 1, "the miss must be accounted on a lane");
}

#[test]
fn corrupted_transforms_are_contained_or_retried() {
    let n = 3;
    let baseline = clean_baseline(n);
    let corrupt = FaultPlan::scripted([(0, FaultKind::CorruptTransform)]);

    // Without a retry budget the NaN-poisoned attempt is final: the job
    // fails contained, named as corruption rather than a data-quality
    // stop, and the rest of the batch is untouched.
    let plan = corrupt.clone();
    let report = run_registration_batch_supervised(
        synthetic_jobs(n),
        1,
        2,
        LaneIcpConfig::default(),
        SupervisorConfig::default(),
        move |_lane, _tier| Ok(FaultInjectingBackend::new(KdTreeCpuBackend::new(), plan.clone())),
    )
    .unwrap();
    assert_exactly_once(&report, n);
    let bad = &report.outcomes[0];
    assert!(bad.is_failed());
    assert!(
        bad.error.as_deref().unwrap_or("").contains("non-finite"),
        "corruption must surface as a non-finite failure: {:?}",
        bad.error
    );
    assert!(bad.rmse.is_nan());
    for o in &report.outcomes[1..] {
        assert!(!o.is_failed());
        assert_bit_identical(o, &baseline.outcomes[o.id as usize]);
    }

    // With one retry the corrupted attempt is re-run cleanly and the
    // result is bit-identical to the never-faulted baseline.
    let sup = SupervisorConfig {
        max_retries: 1,
        ..Default::default()
    };
    let plan = corrupt;
    let report = run_registration_batch_supervised(
        synthetic_jobs(n),
        1,
        2,
        LaneIcpConfig::default(),
        sup,
        move |_lane, _tier| Ok(FaultInjectingBackend::new(KdTreeCpuBackend::new(), plan.clone())),
    )
    .unwrap();
    assert_exactly_once(&report, n);
    for (a, b) in report.outcomes.iter().zip(baseline.outcomes.iter()) {
        assert!(!a.is_failed(), "job {} must recover: {:?}", a.id, a.error);
        assert_bit_identical(a, b);
    }
    assert_eq!(report.outcomes[0].attempts, 2);
}

#[test]
fn seeded_fault_plans_conserve_jobs_and_preserve_clean_results() {
    // The acceptance property, over five distinct seeded plans mixing
    // all four fault kinds: every job accounted for exactly once, every
    // failure carries an error, and every success is bit-identical to
    // the clean sequential run — injection only ever prevents or poisons
    // an attempt, never skews a surviving one.
    let n = 10;
    let baseline = clean_baseline(n);
    for seed in 1..=5u64 {
        let sup = SupervisorConfig {
            deadline: Some(Duration::from_secs(5)),
            max_retries: 6,
            restarts_per_tier: 1,
            ..Default::default()
        };
        let start = Instant::now();
        let report = run_registration_batch_supervised(
            synthetic_jobs(n),
            2,
            2,
            LaneIcpConfig::default(),
            sup,
            move |lane, tier| {
                let p = if tier == 0 {
                    FaultPlan::seeded(seed, lane, 64, 0.2, 150)
                } else {
                    FaultPlan::none()
                };
                Ok(FaultInjectingBackend::new(KdTreeCpuBackend::new(), p))
            },
        )
        .unwrap();
        assert!(
            start.elapsed() < Duration::from_secs(60),
            "seed {seed}: pool must not wedge"
        );
        assert_exactly_once(&report, n);
        for o in &report.outcomes {
            if o.is_failed() {
                assert!(o.error.is_some() && o.rmse.is_nan(), "seed {seed} job {}", o.id);
            } else {
                assert_bit_identical(o, &baseline.outcomes[o.id as usize]);
            }
        }
        let jobs: usize = report.lanes.iter().map(|l| l.jobs).sum();
        assert_eq!(jobs, n, "seed {seed}: per-lane counts must conserve work");
    }
}

#[test]
fn failover_chain_reaches_a_working_backend() {
    let n = 4;
    let baseline = clean_baseline(n);
    // Tier 0 is hopeless — it panics on every align attempt — so only
    // the failover escalation can make progress.
    let sup = SupervisorConfig {
        max_retries: 3,
        restarts_per_tier: 1,
        ..Default::default()
    };
    let report = run_registration_batch_supervised(
        synthetic_jobs(n),
        1,
        2,
        LaneIcpConfig::default(),
        sup,
        move |_lane, tier| {
            let p = if tier == 0 {
                FaultPlan::scripted((0..64).map(|o| (o, FaultKind::Panic)))
            } else {
                FaultPlan::none()
            };
            Ok(FaultInjectingBackend::new(KdTreeCpuBackend::new(), p))
        },
    )
    .unwrap();

    assert_exactly_once(&report, n);
    for (a, b) in report.outcomes.iter().zip(baseline.outcomes.iter()) {
        assert!(!a.is_failed(), "job {} must recover: {:?}", a.id, a.error);
        assert_bit_identical(a, b);
    }
    assert!(report.lanes[0].restarts >= 1);
    assert!(report.lanes[0].backend_tier >= 1, "tier must advance off the panicking backend");
}
