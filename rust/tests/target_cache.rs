//! Integration tests for the cross-frame target cache: the cached
//! (resident-target) path must be bit-identical to fresh-upload on
//! seeded synthetic sequences, the kd-tree backend must build its index
//! exactly once per target upload — including across a whole lane pool
//! via affinity scheduling — a genuinely changed target must invalidate
//! the epoch, and the LRU multi-target residency set must absorb
//! alternating-map (tile ping-pong) workloads: one upload and one
//! kd-tree build *per map*, not per alignment, bit-identical to the
//! single-slot path.

use fpps::coordinator::{
    localization_jobs, run_registration_batch, LaneIcpConfig, PipelineConfig, RegistrationJob,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{FppsIcp, KdTreeCpuBackend, NativeSimBackend};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

fn tiny_sequence(frames: usize) -> Sequence {
    let spec = sequence_specs()[3].clone(); // residential: gentle
    Sequence::synthetic(spec, frames, 11, LidarConfig::tiny())
}

/// Cached-target alignments (one session, resident target) must be
/// bit-identical to fresh-upload alignments (new session per scan) on a
/// seeded synthetic localization sequence — same claim and pattern as
/// `tests/lane_engine.rs`, one layer down.
#[test]
fn cached_target_is_bit_identical_to_fresh_upload() {
    let seq = tiny_sequence(6);
    let cfg = PipelineConfig {
        source_sample: 512,
        target_capacity: 4096,
        ..Default::default()
    };
    let workload = localization_jobs(&seq, 6, &cfg).unwrap();

    // Cached: one FppsIcp session keeps the map resident across scans.
    let mut cached = FppsIcp::kdtree_cpu();
    let mut cached_results = Vec::new();
    for job in &workload.jobs {
        cached.set_input_source(job.source.clone());
        cached.set_input_target(Arc::clone(&job.target));
        cached.set_transformation_matrix(job.initial);
        cached_results.push(cached.align().unwrap());
    }
    assert_eq!(
        cached.backend().tree_builds(),
        1,
        "K scans against one unchanged map: the kd-tree is built exactly once"
    );
    let (uploads, hits, _) = cached.target_cache_stats();
    assert_eq!(uploads, 1);
    assert_eq!(hits as usize, workload.jobs.len() - 1);

    // Fresh: a brand-new session per scan re-uploads (and rebuilds).
    for (job, c) in workload.jobs.iter().zip(&cached_results) {
        let mut fresh = FppsIcp::kdtree_cpu();
        fresh.set_input_source(job.source.clone());
        fresh.set_input_target(Arc::clone(&job.target));
        fresh.set_transformation_matrix(job.initial);
        let f = fresh.align().unwrap();
        assert_eq!(fresh.backend().tree_builds(), 1);
        assert_eq!(f.transformation.m, c.transformation.m, "job {}", job.id);
        assert_eq!(f.rmse.to_bits(), c.rmse.to_bits(), "job {}", job.id);
        assert_eq!(f.iterations, c.iterations, "job {}", job.id);
    }
}

/// Same bit-identity claim for the NativeSim (device-mirror) backend.
#[test]
fn native_sim_cached_target_matches_fresh() {
    let target = structured_cloud(800, 60);
    let gt = Mat4::from_rt(Mat3::rot_z(0.03), Vec3::new(0.2, -0.1, 0.01));
    let sources: Vec<PointCloud> = (0..4)
        .map(|k| {
            let mut rng = Pcg32::new(70 + k);
            let mut s = target.transformed(&gt.inverse_rigid());
            s.add_noise(0.005, &mut rng);
            s
        })
        .collect();

    let mut cached = FppsIcp::native_sim();
    for (k, s) in sources.iter().enumerate() {
        cached.set_input_source(s.clone());
        cached.set_input_target(target.clone());
        let c = cached.align().unwrap();

        let mut fresh = FppsIcp::native_sim();
        fresh.set_input_source(s.clone());
        fresh.set_input_target(target.clone());
        let f = fresh.align().unwrap();
        assert_eq!(f.transformation.m, c.transformation.m, "scan {k}");
        assert_eq!(f.rmse.to_bits(), c.rmse.to_bits(), "scan {k}");
    }
    let (uploads, hits, _) = cached.target_cache_stats();
    assert_eq!((uploads, hits), (1, 3));
}

/// On a *single-slot* backend a genuinely changed target must invalidate
/// the resident epoch — and the post-invalidation results must equal a
/// fresh session's. (This is the thrash the LRU set exists to avoid;
/// see `alternating_maps_upload_once_per_map_with_lru_residency`.)
#[test]
fn target_change_invalidates_epoch() {
    let target_a = structured_cloud(700, 61);
    let target_b = structured_cloud(700, 62);
    let source = target_a.transformed(
        &Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, 0.05, 0.0)).inverse_rigid(),
    );

    let mut icp = FppsIcp::with_backend(KdTreeCpuBackend::with_residency_slots(1));
    for (round, tgt) in [&target_a, &target_b, &target_a, &target_b].iter().enumerate() {
        icp.set_input_source(source.clone());
        icp.set_input_target((*tgt).clone());
        let c = icp.align().unwrap();
        assert_eq!(
            icp.backend().tree_builds(),
            round as u64 + 1,
            "one slot: every target change rebuilds"
        );

        let mut fresh = FppsIcp::kdtree_cpu();
        fresh.set_input_source(source.clone());
        fresh.set_input_target((*tgt).clone());
        let f = fresh.align().unwrap();
        assert_eq!(f.transformation.m, c.transformation.m, "round {round}");
        assert_eq!(f.rmse.to_bits(), c.rmse.to_bits(), "round {round}");
    }
    let (uploads, hits, _) = icp.target_cache_stats();
    assert_eq!((uploads, hits), (4, 0), "alternating targets never hit");
}

/// Acceptance criterion of the LRU residency set: a two-map alternating
/// workload (A,B,A,B,…) on a backend with ≥ 2 residency slots performs
/// exactly 2 target uploads and 1 kd-tree build per map, with
/// transforms bit-identical to the single-slot path.
#[test]
fn alternating_maps_upload_once_per_map_with_lru_residency() {
    let map_a = Arc::new(structured_cloud(700, 63));
    let map_b = Arc::new(structured_cloud(700, 64));
    let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, 0.05, 0.0));
    // Eight scans ping-ponging A,B,A,B,… with per-scan noise.
    let jobs: Vec<(Arc<PointCloud>, PointCloud)> = (0..8u64)
        .map(|k| {
            let map = if k % 2 == 0 { &map_a } else { &map_b };
            let mut rng = Pcg32::new(200 + k);
            let mut s = map.transformed(&gt.inverse_rigid());
            s.add_noise(0.005, &mut rng);
            (Arc::clone(map), s.random_sample(300, &mut rng))
        })
        .collect();

    let mut multi = FppsIcp::kdtree_cpu();
    assert!(
        multi.backend().residency_slots() >= 2,
        "hwmodel budget must grant at least two slots"
    );
    let mut multi_results = Vec::new();
    for (map, src) in &jobs {
        multi.set_input_source(src.clone());
        multi.set_input_target(Arc::clone(map));
        multi_results.push(multi.align().unwrap());
    }
    let (uploads, hits, _) = multi.target_cache_stats();
    assert_eq!(uploads, 2, "exactly one upload per map");
    assert_eq!(hits, 6, "every revisit is a cache hit");
    assert_eq!(
        multi.backend().tree_builds(),
        2,
        "exactly one kd-tree build per map"
    );
    // Both maps are still resident afterwards.
    assert_eq!(multi.backend().resident_epochs().len(), 2);

    // Single-slot path: thrashes (8 uploads) but must stay bit-identical.
    let mut single = FppsIcp::with_backend(KdTreeCpuBackend::with_residency_slots(1));
    for ((map, src), m) in jobs.iter().zip(&multi_results) {
        single.set_input_source(src.clone());
        single.set_input_target(Arc::clone(map));
        let s = single.align().unwrap();
        assert_eq!(s.transformation.m, m.transformation.m);
        assert_eq!(s.rmse.to_bits(), m.rmse.to_bits());
        assert_eq!(s.iterations, m.iterations);
    }
    let (single_uploads, single_hits, _) = single.target_cache_stats();
    assert_eq!((single_uploads, single_hits), (8, 0));
    assert_eq!(single.backend().tree_builds(), 8);
}

/// Across a whole lane pool, affinity scheduling keeps the shared map
/// resident: a single lane builds the kd-tree exactly once for the whole
/// batch, K lanes build it at most once *per lane* (the dispatcher may
/// steal to an idle lane for parallelism) — and the outcomes stay
/// bit-identical between the two.
#[test]
fn lane_pool_builds_shared_map_once_per_lane() {
    let seq = tiny_sequence(6);
    let cfg = PipelineConfig {
        source_sample: 512,
        target_capacity: 4096,
        ..Default::default()
    };
    let icp_cfg = LaneIcpConfig {
        max_iteration_count: 30,
        ..Default::default()
    };

    // One lane: deterministic — six same-map jobs, exactly one build.
    let builds = Arc::new(AtomicU64::new(0));
    let builds_ref = Arc::clone(&builds);
    let sequential = run_registration_batch(
        localization_jobs(&seq, 6, &cfg).unwrap().jobs,
        1,
        2,
        icp_cfg,
        move |_lane| {
            let counter = Arc::clone(&builds_ref);
            Ok(KdTreeCpuBackend::with_shared_build_counter(counter))
        },
    )
    .unwrap();
    assert_eq!(sequential.outcomes.len(), 6);
    assert_eq!(
        builds.load(Ordering::Relaxed),
        1,
        "six scans, one unchanged map: the kd-tree is built exactly once"
    );

    // Two lanes: at most one build per lane, never one per scan.
    let builds2 = Arc::new(AtomicU64::new(0));
    let builds2_ref = Arc::clone(&builds2);
    let pooled = run_registration_batch(
        localization_jobs(&seq, 6, &cfg).unwrap().jobs,
        2,
        16,
        icp_cfg,
        move |_lane| {
            let counter = Arc::clone(&builds2_ref);
            Ok(KdTreeCpuBackend::with_shared_build_counter(counter))
        },
    )
    .unwrap();
    assert_eq!(pooled.outcomes.len(), 6);
    let b = builds2.load(Ordering::Relaxed);
    assert!((1..=2).contains(&b), "expected ≤ 1 build per lane, got {b}");

    for (a, b) in sequential.outcomes.iter().zip(pooled.outcomes.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
        assert_eq!(a.iterations, b.iterations);
    }
}

/// Mixed-target batches still conserve work under affinity scheduling,
/// and per-lane upload/hit accounting adds up.
#[test]
fn affinity_scheduler_conserves_work_on_mixed_targets() {
    let map_a = Arc::new(structured_cloud(600, 80));
    let map_b = Arc::new(structured_cloud(600, 81));
    let gt = Mat4::from_rt(Mat3::rot_z(0.01), Vec3::new(0.05, 0.0, 0.0));
    let jobs: Vec<_> = (0..10u64)
        .map(|k| {
            let map = if k % 2 == 0 { &map_a } else { &map_b };
            let mut rng = Pcg32::new(90 + k);
            let mut source = map.transformed(&gt.inverse_rigid());
            source.add_noise(0.005, &mut rng);
            RegistrationJob::new(
                k,
                (k % 2) as usize,
                source.random_sample(300, &mut rng),
                Arc::clone(map),
                Mat4::IDENTITY,
            )
        })
        .collect();

    let report = run_registration_batch(jobs, 2, 16, LaneIcpConfig::default(), |_| {
        Ok(NativeSimBackend::new())
    })
    .unwrap();
    assert_eq!(report.outcomes.len(), 10);
    let served: usize = report.lanes.iter().map(|l| l.jobs).sum();
    assert_eq!(served, 10);
    let uploads: usize = report.lanes.iter().map(|l| l.target_uploads).sum();
    let hits: usize = report.lanes.iter().map(|l| l.target_hits).sum();
    assert_eq!(uploads + hits, 10, "every job uploads or hits");
    // Two distinct maps: at least one upload each; the exact split
    // depends on steal timing, but LRU residency bounds it by
    // maps x lanes rather than by the job count.
    assert!(uploads >= 2, "both maps must be uploaded at least once");
    assert!(uploads <= 4, "uploads bounded by maps x lanes, got {uploads}");
    // Queue-wait accounting reached the per-lane stats (satellite:
    // lane_table renders these).
    let waits: usize = report.lanes.iter().map(|l| l.queue_wait.count()).sum();
    assert_eq!(waits, 10);
}
