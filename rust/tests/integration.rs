//! Integration tests across the three layers: the AOT artifact executed
//! on the PJRT runtime vs the NativeSim mirror vs the CPU baseline.
//!
//! These tests require `artifacts/` (run `make artifacts` first); they
//! skip — loudly — when it is absent so `cargo test` still passes in a
//! python-less checkout.

use fpps::fpps_api::{FppsIcp, KernelBackend, NativeSimBackend, XlaBackend};
use fpps::icp::{IcpParams, StopReason};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    if !cfg!(feature = "xla") {
        eprintln!("SKIP: built without the `xla` feature — PJRT runtime unavailable");
        return None;
    }
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let candidates = [
        PathBuf::from("artifacts"),
        manifest_dir.join("artifacts"),
        manifest_dir.join("../artifacts"),
    ];
    for c in candidates {
        if c.join("manifest.txt").exists() {
            return Some(c);
        }
    }
    eprintln!("SKIP: artifacts/ not found — run `make artifacts`");
    None
}

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 4 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            2 => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
            _ => c.push([
                rng.range(-5.0, 5.0),
                rng.range(-5.0, 5.0),
                rng.range(0.0, 2.0),
            ]),
        }
    }
    c
}

#[test]
fn xla_backend_loads_and_reports_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = XlaBackend::load(&dir).expect("load artifacts");
    let m = backend.engine().manifest();
    assert!(m.variants.len() >= 3);
    // Capacity selection picks the smallest fit.
    let (n, mcap, bn, bm) = backend.select_capacity(200, 900).unwrap();
    assert_eq!((n, mcap), (256, 1024));
    assert!(bn > 0 && bm > 0);
    assert!(backend.select_capacity(100_000, 100).is_err());
}

#[test]
fn xla_step_matches_native_sim_step() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaBackend::load(&dir).expect("load artifacts");
    // Pick the smallest variant and its block config for the mirror.
    let (n, m, bn, bm) = xla.select_capacity(1, 1).unwrap();
    let mut sim = NativeSimBackend::with_blocks(bn, bm);

    let mut rng = Pcg32::new(42);
    let mut src = vec![0f32; n * 3];
    let mut tgt = vec![0f32; m * 3];
    for v in src.iter_mut().chain(tgt.iter_mut()) {
        *v = rng.range(-8.0, 8.0);
    }
    let mut smask = vec![1f32; n];
    let mut tmask = vec![1f32; m];
    // Realistic padding tail.
    for v in smask[n - 13..].iter_mut() {
        *v = 0.0;
    }
    for v in tmask[m - 57..].iter_mut() {
        *v = 0.0;
    }
    let t = Mat4::from_rt(Mat3::rot_z(0.1), Vec3::new(0.3, -0.2, 0.05));

    let a = xla
        .icp_step(&src, &tgt, &smask, &tmask, &t, 4.0)
        .expect("xla step");
    let b = sim
        .icp_step(&src, &tgt, &smask, &tmask, &t, 4.0)
        .expect("sim step");

    assert_eq!(a.count, b.count, "correspondence counts differ");
    let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1.0);
    assert!(rel(a.sum_sq_dist, b.sum_sq_dist) < 1e-3,
        "sum_sq {} vs {}", a.sum_sq_dist, b.sum_sq_dist);
    assert!((a.sum_p - b.sum_p).norm() < 1e-2 * (1.0 + b.sum_p.norm()));
    assert!((a.sum_q - b.sum_q).norm() < 1e-2 * (1.0 + b.sum_q.norm()));
    for i in 0..3 {
        for j in 0..3 {
            assert!(
                rel(a.sum_pq.m[i][j], b.sum_pq.m[i][j]) < 1e-3,
                "sum_pq[{i}][{j}]: {} vs {}",
                a.sum_pq.m[i][j],
                b.sum_pq.m[i][j]
            );
        }
    }
}

#[test]
fn xla_alignment_recovers_transform() {
    let Some(dir) = artifacts_dir() else { return };
    let target = structured_cloud(900, 1);
    let gt = Mat4::from_rt(Mat3::rot_z(0.04), Vec3::new(0.25, -0.1, 0.02));
    let source = target.transformed(&gt.inverse_rigid());

    let mut icp = FppsIcp::hardware_initialize(&dir).expect("init");
    icp.set_input_source(source)
        .set_input_target(target)
        .set_max_correspondence_distance(1.0)
        .set_max_iteration_count(50)
        .set_transformation_epsilon(1e-5);
    let res = icp.align().expect("align");
    assert!(res.has_converged(), "stop = {:?}", res.stop);
    let rerr = res
        .transformation
        .rotation()
        .rotation_angle_to(&gt.rotation());
    let terr = (res.transformation.translation() - gt.translation()).norm();
    assert!(rerr < 2e-3, "rotation error {rerr}");
    assert!(terr < 2e-2, "translation error {terr}");
}

#[test]
fn xla_and_native_sim_agree_end_to_end() {
    // The Table III backend-parity claim: same clouds, same parameters
    // → same transform and RMSE within float noise (≪ 0.01 m).
    let Some(dir) = artifacts_dir() else { return };
    let target = structured_cloud(1000, 2);
    let gt = Mat4::from_rt(Mat3::rot_z(-0.03), Vec3::new(-0.2, 0.15, 0.01));
    let mut source = target.transformed(&gt.inverse_rigid());
    let mut rng = Pcg32::new(3);
    source.add_noise(0.01, &mut rng);

    let mut xla_icp = FppsIcp::hardware_initialize(&dir).expect("init");
    xla_icp
        .set_input_source(source.clone())
        .set_input_target(target.clone());
    let a = xla_icp.align().expect("xla align");

    let mut sim_icp = FppsIcp::native_sim();
    sim_icp.set_input_source(source).set_input_target(target);
    let b = sim_icp.align().expect("sim align");

    assert!((a.rmse - b.rmse).abs() < 1e-3, "rmse {} vs {}", a.rmse, b.rmse);
    let dt = (a.transformation.translation() - b.transformation.translation()).norm();
    assert!(dt < 1e-3, "translations differ by {dt}");
}

#[test]
fn xla_matches_cpu_baseline_within_table3_margin() {
    // CPU (kd-tree, f64 host accumulation) vs device (blocked f32):
    // the paper's Table III consistency claim, Δrmse < 0.01 m.
    let Some(dir) = artifacts_dir() else { return };
    let target = structured_cloud(1000, 5);
    let gt = Mat4::from_rt(Mat3::rot_z(0.03), Vec3::new(0.2, 0.1, -0.01));
    let mut source = target.transformed(&gt.inverse_rigid());
    let mut rng = Pcg32::new(6);
    source.add_noise(0.01, &mut rng);

    let cpu = fpps::icp::align(&source, &target, &Mat4::IDENTITY, &IcpParams::default());
    assert!(cpu.has_converged());

    let mut icp = FppsIcp::hardware_initialize(&dir).expect("init");
    icp.set_input_source(source).set_input_target(target);
    let dev = icp.align().expect("align");
    assert!(dev.has_converged());

    assert!(
        (cpu.rmse - dev.rmse).abs() < 0.01,
        "Table III margin violated: cpu {} vs device {}",
        cpu.rmse,
        dev.rmse
    );
}

#[test]
fn variant_padding_does_not_change_result() {
    // Aligning the same clouds through two different capacity variants
    // (different padding) must give the same answer.
    let Some(dir) = artifacts_dir() else { return };
    let target = structured_cloud(700, 7); // fits 1024 and 4096 variants
    let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, 0.05, 0.0));
    let source_small = target.transformed(&gt.inverse_rigid()).random_sample(
        200,
        &mut Pcg32::new(8),
    );
    let source_big = {
        // Same points replicated to force the bigger variant.
        let mut c = source_small.clone();
        let extra = structured_cloud(400, 9).transformed(&gt.inverse_rigid());
        for p in extra.iter() {
            c.push(p);
        }
        c
    };

    let mut icp = FppsIcp::hardware_initialize(&dir).expect("init");
    icp.set_input_source(source_small).set_input_target(target.clone());
    let small = icp.align().expect("small align");

    icp.set_input_source(source_big).set_input_target(target);
    let big = icp.align().expect("big align");

    // Different source sets → different exact transforms, but both must
    // recover gt to similar accuracy (padding itself must not bias).
    for res in [&small, &big] {
        let terr = (res.transformation.translation() - gt.translation()).norm();
        assert!(terr < 0.05, "terr {terr}");
    }
}

#[test]
fn coordinator_runs_on_xla_backend() {
    // Mini end-to-end: 4 synthetic frames through the odometry pipeline
    // with the real AOT artifact in the loop.
    let Some(dir) = artifacts_dir() else { return };
    use fpps::coordinator::{run_odometry, PipelineConfig};
    use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
    let spec = sequence_specs()[3].clone();
    let seq = Sequence::synthetic(
        spec,
        4,
        11,
        LidarConfig {
            beams: 32,
            azimuth_steps: 600,
            ..Default::default()
        },
    );
    let mut icp = FppsIcp::hardware_initialize(&dir).expect("init");
    icp.set_max_iteration_count(25);
    let cfg = PipelineConfig {
        source_sample: 1024,
        target_capacity: 4096,
        ..Default::default()
    };
    let res = run_odometry(&seq, 4, cfg, &mut icp).expect("odometry");
    assert_eq!(res.records.len(), 3);
    for r in &res.records {
        assert!(r.stop != StopReason::TooFewCorrespondences);
    }
}
