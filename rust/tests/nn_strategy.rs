//! NN-strategy integration suite: the voxel-grid index against the
//! exact kd-tree path, end to end through `FppsIcp`.
//!
//! The contract under test (ISSUE 8):
//! * `NnStrategy::Exact` (and `Auto` below its map-size threshold) is
//!   **bit-identical** to the historical kd-tree path;
//! * `Approx` with a ring budget covering the correspondence radius is
//!   bit-identical too, through the grid code path;
//! * `Approx` with a tight budget holds a bounded RMSE delta on the
//!   table3-style workloads;
//! * chunked NN queries stop between chunks when the cancellation token
//!   is raised, with observable progress counters;
//! * `kdtree::nearest_approximate` degenerates to the exact search with
//!   an unlimited budget and never reports a fake distance.

use fpps::bench_support::{run_fpps, SeqResult};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{
    CancelToken, FppsIcp, KdTreeCpuBackend, KernelBackend, NativeSimBackend, NN_QUERY_CHUNK,
};
use fpps::kdtree::KdTree;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::prop::{default_cases, forall};
use fpps::rng::Pcg32;
use fpps::voxelgrid::NnStrategy;

/// Structured cloud (two walls + floor patch), the ICP-friendly
/// geometry the chaos/property suites use.
fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

fn small_transform(rng: &mut Pcg32) -> Mat4 {
    let r = Mat3::axis_angle([0.0, 0.0, 1.0], rng.range(-0.05, 0.05));
    let t = Vec3::new(
        rng.range(-0.3, 0.3) as f64,
        rng.range(-0.3, 0.3) as f64,
        rng.range(-0.05, 0.05) as f64,
    );
    Mat4::from_rt(r, t)
}

fn kdtree_icp(strategy: NnStrategy) -> FppsIcp<KdTreeCpuBackend> {
    let mut b = KdTreeCpuBackend::new();
    b.set_nn_strategy(strategy);
    FppsIcp::with_backend(b)
}

fn align_once(
    icp: &mut FppsIcp<KdTreeCpuBackend>,
    source: &PointCloud,
    target: &PointCloud,
) -> fpps::fpps_api::FppsResult {
    icp.set_input_source(source.clone())
        .set_input_target(target.clone());
    icp.align().expect("alignment runs")
}

fn assert_bit_identical(
    a: &fpps::fpps_api::FppsResult,
    b: &fpps::fpps_api::FppsResult,
    label: &str,
) {
    assert_eq!(a.transformation.m, b.transformation.m, "{label}: transform");
    assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "{label}: rmse");
    assert_eq!(a.iterations, b.iterations, "{label}: iterations");
}

#[test]
fn exact_and_small_map_auto_are_bit_identical_to_the_kdtree_path() {
    // Property: the strategy knob at `Exact` — and `Auto` on maps below
    // its threshold — must not perturb a single bit of the historical
    // kd-tree backend path.
    forall(default_cases(6), |g| {
        let seed = g.case + 300;
        let target = structured_cloud(900, seed);
        let mut rng = Pcg32::new(seed + 1);
        let source = target.transformed(&small_transform(&mut rng).inverse_rigid());
        let baseline = align_once(&mut FppsIcp::kdtree_cpu(), &source, &target);
        let exact = align_once(&mut kdtree_icp(NnStrategy::Exact), &source, &target);
        let auto = align_once(&mut kdtree_icp(NnStrategy::Auto), &source, &target);
        assert_bit_identical(&baseline, &exact, "exact strategy");
        assert_bit_identical(&baseline, &auto, "auto on a small map");
    });
}

#[test]
fn covering_budget_approx_is_bit_identical_through_the_grid_path() {
    // Approx with max_ring·cell ≥ max correspondence distance answers
    // every bounded NN query exactly, so even the *grid* code path must
    // reproduce the kd-tree alignment bit for bit.
    forall(default_cases(4), |g| {
        let seed = g.case + 400;
        let target = structured_cloud(1000, seed);
        let mut rng = Pcg32::new(seed + 1);
        let source = target.transformed(&small_transform(&mut rng).inverse_rigid());
        let baseline = align_once(&mut FppsIcp::kdtree_cpu(), &source, &target);
        let covering = NnStrategy::Approx {
            cell_size: 1.0,
            max_ring: 2,
        };
        let mut icp = kdtree_icp(covering);
        let approx = align_once(&mut icp, &source, &target);
        assert!(
            icp.backend().active_target_uses_grid(),
            "approx strategy must route through the grid"
        );
        assert_bit_identical(&baseline, &approx, "covering-budget approx");
    });
}

/// Run the table3 machinery (synthetic stand-ins for the paper's KITTI
/// sequences through `bench_support::run_fpps`) with one strategy.
fn table3_run(spec_idx: usize, frames: usize, strategy: NnStrategy) -> SeqResult {
    let spec = sequence_specs()[spec_idx].clone();
    let seq = Sequence::synthetic(
        spec,
        frames,
        2026,
        LidarConfig {
            beams: 32,
            azimuth_steps: 500,
            ..Default::default()
        },
    );
    let mut icp = kdtree_icp(strategy);
    run_fpps(&seq, frames, &mut icp).expect("table3 workload runs")
}

#[test]
fn approx_holds_bounded_rmse_delta_on_table3_workloads() {
    for spec_idx in [1, 4] {
        let exact = table3_run(spec_idx, 3, NnStrategy::Exact);
        // Covering budget: the grid path, zero approximation — the
        // ISSUE's ≤ 1e-3 mean-RMSE bound holds with margin (delta 0).
        let covering = table3_run(
            spec_idx,
            3,
            NnStrategy::Approx {
                cell_size: 1.0,
                max_ring: 2,
            },
        );
        let delta = (covering.mean_rmse - exact.mean_rmse).abs();
        assert!(
            delta <= 1e-3,
            "seq {spec_idx}: covering-budget approx drifted {delta} \
             ({} vs {})",
            covering.mean_rmse,
            exact.mean_rmse
        );
        // Tight budget (0.5 m cells, 2 rings < the 1 m radius): real
        // approximation, still a bounded drift on the same workload.
        let tight = table3_run(
            spec_idx,
            3,
            NnStrategy::Approx {
                cell_size: 0.5,
                max_ring: 2,
            },
        );
        assert!(
            tight.mean_rmse.is_finite(),
            "seq {spec_idx}: tight-budget run must still converge"
        );
        let drift = (tight.mean_rmse - exact.mean_rmse).abs();
        assert!(
            drift <= 0.05,
            "seq {spec_idx}: tight-budget drift {drift} exceeds the sanity bound \
             ({} vs {})",
            tight.mean_rmse,
            exact.mean_rmse
        );
    }
}

#[test]
fn chunked_step_stops_between_chunks_when_cancelled() {
    // Backend-level half of the watchdog story (the pool-level half
    // lives in tests/chaos.rs): a raised token makes step() bail at a
    // chunk boundary with progress observable, and a cleared token lets
    // the same backend finish and count its chunks.
    let n_src = 3 * NN_QUERY_CHUNK / 2; // 2 chunks
    let target = structured_cloud(4000, 71);
    let source = structured_cloud(n_src, 72);
    let mask_t = vec![1.0f32; target.len()];
    let mask_s = vec![1.0f32; source.len()];
    let mut b = KdTreeCpuBackend::new();
    let token = CancelToken::new();
    b.set_cancel_token(token.clone());
    b.upload_target(&target.xyz, &mask_t).unwrap();
    b.upload_source(&source.xyz, &mask_s).unwrap();

    token.cancel();
    let err = b.step(&Mat4::IDENTITY, 1.0).unwrap_err();
    assert!(
        err.to_string().contains("cancelled between NN query chunks"),
        "unexpected error: {err:#}"
    );
    let (chunks, cancels) = b.nn_progress();
    assert_eq!(cancels, 1, "the cut-off must be counted");
    assert_eq!(chunks, 0, "pre-raised token stops before the first chunk");

    token.reset();
    b.step(&Mat4::IDENTITY, 1.0).unwrap();
    let (chunks, cancels) = b.nn_progress();
    assert_eq!(cancels, 1);
    assert_eq!(
        chunks as usize,
        n_src.div_ceil(NN_QUERY_CHUNK),
        "a clean step completes every chunk"
    );
}

#[test]
fn native_sim_honours_cancellation_too() {
    let target = structured_cloud(600, 73);
    let source = structured_cloud(600, 74);
    let mask = vec![1.0f32; 600];
    let mut b = NativeSimBackend::new();
    let token = CancelToken::new();
    b.set_cancel_token(token.clone());
    b.upload_target(&target.xyz, &mask).unwrap();
    b.upload_source(&source.xyz, &mask).unwrap();
    token.cancel();
    let err = b.step(&Mat4::IDENTITY, 1.0).unwrap_err();
    assert!(err.to_string().contains("cancelled"), "{err:#}");
    token.reset();
    b.step(&Mat4::IDENTITY, 1.0).unwrap();
}

#[test]
fn strategy_knob_is_visible_through_the_backend_trait() {
    let mut b = KdTreeCpuBackend::new();
    assert_eq!(b.nn_strategy(), NnStrategy::Exact, "inert default");
    let approx = NnStrategy::Approx {
        cell_size: 0.5,
        max_ring: 3,
    };
    b.set_nn_strategy(approx);
    assert_eq!(b.nn_strategy(), approx);
    // Exact never builds a grid; approx always does.
    let target = structured_cloud(500, 75);
    let mask = vec![1.0f32; target.len()];
    b.upload_target(&target.xyz, &mask).unwrap();
    assert!(b.active_target_uses_grid());
    b.set_nn_strategy(NnStrategy::Exact);
    b.upload_target_keyed(2, &target.xyz, &mask).unwrap();
    assert!(!b.active_target_uses_grid(), "exact slot carries no grid");
}

#[test]
fn kdtree_nearest_approximate_error_bound_against_exact() {
    // Satellite: `kdtree::nearest_approximate` has never been covered.
    // Unlimited budget must degenerate to the exact search bit for bit;
    // any bounded budget must report a *real* distance (to the returned
    // index) that is never better than the true nearest.
    let cloud = structured_cloud(1500, 81);
    let tree = KdTree::build(&cloud);
    let mut rng = Pcg32::new(82);
    for _ in 0..400 {
        let q = [
            rng.range(-6.0, 6.0),
            rng.range(-6.0, 6.0),
            rng.range(-1.0, 4.0),
        ];
        let exact = tree.nearest(q).expect("non-empty tree");
        let unlimited = tree
            .nearest_approximate(q, usize::MAX)
            .expect("unlimited budget always finds a point");
        assert_eq!(unlimited.dist_sq.to_bits(), exact.dist_sq.to_bits());
        assert_eq!(unlimited.index, exact.index);
        for budget in [1usize, 4, 16] {
            let approx = tree
                .nearest_approximate(q, budget)
                .expect("budget ≥ 1 visits at least one leaf");
            let p = cloud.get(approx.index as usize);
            let d2 = (p[0] - q[0]).powi(2) + (p[1] - q[1]).powi(2) + (p[2] - q[2]).powi(2);
            assert_eq!(
                approx.dist_sq.to_bits(),
                d2.to_bits(),
                "reported distance must belong to the reported point"
            );
            assert!(
                approx.dist_sq >= exact.dist_sq,
                "approximate search cannot beat the exact nearest"
            );
        }
    }
}
