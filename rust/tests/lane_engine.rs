//! Integration tests for the multi-lane batched registration engine:
//! determinism under concurrency (K lanes must produce bit-identical
//! transforms to the sequential path on a seeded synthetic sequence),
//! work conservation, and the backend-per-lane plumbing.

use fpps::coordinator::{
    run_registration_batch, sequence_pair_jobs, LaneIcpConfig, PipelineConfig,
    RegistrationJob,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{BackendHandle, BackendKind, NativeSimBackend};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;
use std::path::Path;

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

/// Independent seeded frame-pair jobs spread over three logical streams.
fn synthetic_jobs(n: usize) -> Vec<RegistrationJob> {
    (0..n)
        .map(|k| {
            let target = structured_cloud(600, 100 + k as u64);
            let gt = Mat4::from_rt(
                Mat3::rot_z(0.01 * (k as f64 + 1.0)),
                Vec3::new(0.1 + 0.02 * k as f64, -0.05, 0.01),
            );
            let source = target.transformed(&gt.inverse_rigid());
            RegistrationJob::new(k as u64, k % 3, source, target, Mat4::IDENTITY)
        })
        .collect()
}

#[test]
fn k_lanes_match_sequential_bitwise() {
    let cfg = LaneIcpConfig::default();
    let seq = run_registration_batch(synthetic_jobs(8), 1, 2, cfg, |_| {
        Ok(NativeSimBackend::new())
    })
    .unwrap();
    let par = run_registration_batch(synthetic_jobs(8), 4, 2, cfg, |_| {
        Ok(NativeSimBackend::new())
    })
    .unwrap();

    assert_eq!(seq.outcomes.len(), 8);
    assert_eq!(par.outcomes.len(), 8);
    for (a, b) in seq.outcomes.iter().zip(par.outcomes.iter()) {
        assert_eq!(a.id, b.id, "outcome order must be id order");
        assert_eq!(a.stream, b.stream);
        // Bit-identical transforms: concurrency must not change numerics.
        assert_eq!(a.transform.m, b.transform.m, "job {} transform", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {} rmse", a.id);
        assert_eq!(a.iterations, b.iterations, "job {} iterations", a.id);
        assert_eq!(a.stop, b.stop);
    }
}

#[test]
fn serving_tier_matches_sequential_bitwise() {
    // Same determinism claim through the serving tier: a job accepted
    // by `ServingPool::submit` runs the exact same lane-pool path as a
    // batch submission, so Ok outcomes stay bit-identical.
    use fpps::coordinator::{ServingConfig, ServingPool, SupervisorConfig};
    let cfg = LaneIcpConfig::default();
    let seq = run_registration_batch(synthetic_jobs(8), 1, 2, cfg, |_| {
        Ok(NativeSimBackend::new())
    })
    .unwrap();

    let pool = ServingPool::start(
        3,
        2,
        cfg,
        SupervisorConfig::default(),
        ServingConfig::default(),
        |_lane, _tier| Ok(NativeSimBackend::new()),
    )
    .unwrap();
    let handles: Vec<_> = synthetic_jobs(8)
        .into_iter()
        .map(|j| pool.submit(j).unwrap())
        .collect();
    let served: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
    let report = pool.shutdown().unwrap();
    assert_eq!(report.total_shed(), 0);

    for (a, b) in seq.outcomes.iter().zip(served.iter()) {
        assert_eq!(a.id, b.id, "handles resolve in submission (= id) order");
        assert_eq!(a.transform.m, b.transform.m, "job {} transform", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {} rmse", a.id);
        assert_eq!(a.iterations, b.iterations, "job {} iterations", a.id);
        assert_eq!(a.stop, b.stop);
    }
}

#[test]
fn lanes_match_on_a_seeded_synthetic_sequence() {
    // Same claim at system level: frame pairs cut from one seeded
    // synthetic LiDAR sequence, shared job generator, 1 vs 3 lanes.
    let spec = sequence_specs()[3].clone();
    let seq = Sequence::synthetic(spec, 6, 77, LidarConfig::tiny());
    let cfg = PipelineConfig {
        source_sample: 512,
        target_capacity: 4096,
        ..Default::default()
    };
    let jobs_a = sequence_pair_jobs(&seq, 6, 0, &cfg).unwrap();
    let jobs_b = sequence_pair_jobs(&seq, 6, 0, &cfg).unwrap();
    assert_eq!(jobs_a.len(), 5);

    let icp = LaneIcpConfig {
        max_iteration_count: 30,
        ..Default::default()
    };
    let one = run_registration_batch(jobs_a, 1, 2, icp, |_| Ok(NativeSimBackend::new()))
        .unwrap();
    let three = run_registration_batch(jobs_b, 3, 2, icp, |_| Ok(NativeSimBackend::new()))
        .unwrap();
    for (a, b) in one.outcomes.iter().zip(three.outcomes.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.iterations, b.iterations);
    }
}

#[test]
fn lane_report_conserves_work_and_merges_stats() {
    let n = 9;
    let lanes = 3;
    let report = run_registration_batch(
        synthetic_jobs(n),
        lanes,
        2,
        LaneIcpConfig::default(),
        |_| Ok(NativeSimBackend::new()),
    )
    .unwrap();

    assert_eq!(report.outcomes.len(), n);
    assert_eq!(report.lanes.len(), lanes);
    // Every job served exactly once; per-lane counts sum to the total.
    let per_lane_total: usize = report.lanes.iter().map(|l| l.jobs).sum();
    assert_eq!(per_lane_total, n);
    // Aggregate distribution is the merge of the per-lane ones.
    let merged: usize = report.lanes.iter().map(|l| l.service.count()).sum();
    assert_eq!(report.service.count(), merged);
    assert_eq!(report.service.count(), n);
    assert_eq!(report.queue_wait.count(), n);
    assert!(report.wall_ms > 0.0);
    assert!(report.jobs_per_s() > 0.0);
    // Lane indices recorded on outcomes stay within range.
    for o in &report.outcomes {
        assert!(o.lane < lanes);
        assert!(o.service_ms > 0.0);
        assert!(o.rmse.is_finite());
    }
}

#[test]
fn lane_pool_supports_backend_handles_per_lane() {
    // Each lane resolves its own BackendHandle at runtime — the
    // multi-backend dispatch the engine is built around.
    let report = run_registration_batch(
        synthetic_jobs(4),
        2,
        2,
        LaneIcpConfig::default(),
        |_lane| BackendHandle::create(BackendKind::NativeSim, Path::new("artifacts")),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 4);
    for o in &report.outcomes {
        assert!(o.iterations >= 1);
    }
}

#[test]
fn kdtree_lanes_agree_with_each_other() {
    // The kd-tree CPU backend is deterministic too: 1 vs 2 lanes agree.
    let cfg = LaneIcpConfig::default();
    let a = run_registration_batch(synthetic_jobs(4), 1, 2, cfg, |_| {
        Ok(fpps::fpps_api::KdTreeCpuBackend::new())
    })
    .unwrap();
    let b = run_registration_batch(synthetic_jobs(4), 2, 2, cfg, |_| {
        Ok(fpps::fpps_api::KdTreeCpuBackend::new())
    })
    .unwrap();
    for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
        assert_eq!(x.transform.m, y.transform.m);
    }
}
