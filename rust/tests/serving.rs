//! Integration tests for the event-driven serving tier: non-blocking
//! submission handles, SLO-classed admission (park vs shed), structured
//! shed outcomes, waker-style completion events, and bit-identical
//! agreement with the batch path for Ok outcomes.

use fpps::coordinator::{
    run_registration_batch, LaneIcpConfig, RegistrationJob, ServingConfig, ServingPool, SloClass,
    Submission, SupervisorConfig,
};
use fpps::fpps_api::NativeSimBackend;
use fpps::icp::StopReason;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;
use std::time::Duration;

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

/// One seeded frame-pair job; calling this twice with the same id
/// builds bit-identical inputs.
fn job(id: u64) -> RegistrationJob {
    let target = structured_cloud(600, 100 + id);
    let gt = Mat4::from_rt(
        Mat3::rot_z(0.01 * (id as f64 + 1.0)),
        Vec3::new(0.1 + 0.02 * id as f64, -0.05, 0.01),
    );
    let source = target.transformed(&gt.inverse_rigid());
    RegistrationJob::new(id, id as usize % 3, source, target, Mat4::IDENTITY)
}

fn pool(lanes: usize, cfg: ServingConfig) -> ServingPool {
    ServingPool::start(
        lanes,
        2,
        LaneIcpConfig::default(),
        SupervisorConfig::default(),
        cfg,
        |_lane, _tier| Ok(NativeSimBackend::new()),
    )
    .unwrap()
}

#[test]
fn submit_resolves_handles_with_real_outcomes() {
    let p = pool(2, ServingConfig::default());
    let handles: Vec<_> = (0..6).map(|k| p.submit(job(k)).unwrap()).collect();
    let outcomes: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
    for (k, o) in outcomes.iter().enumerate() {
        assert_eq!(o.id, k as u64);
        assert!(!o.is_failed(), "job {k}: {:?}", o.error);
        assert!(o.rmse.is_finite());
    }
    let report = p.shutdown().unwrap();
    assert_eq!(report.lane_report.outcomes.len(), 6);
    assert_eq!(report.total_shed(), 0);
    assert_eq!(report.contained_failures(), 0);
    // Per-class accounting: all six were standard submissions.
    let std_stats = report
        .classes
        .iter()
        .find(|c| c.class == SloClass::Standard)
        .unwrap();
    assert_eq!(std_stats.submitted, 6);
    assert_eq!(std_stats.completed, 6);
    assert_eq!(std_stats.ok, 6);
    assert_eq!(std_stats.latency.count(), 6);
}

#[test]
fn serving_matches_batch_bitwise_for_ok_outcomes() {
    let batch = run_registration_batch(
        (0..5).map(job).collect(),
        1,
        2,
        LaneIcpConfig::default(),
        |_| Ok(NativeSimBackend::new()),
    )
    .unwrap();

    let p = pool(3, ServingConfig::default());
    let handles: Vec<_> = (0..5).map(|k| p.submit(job(k)).unwrap()).collect();
    let served: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
    p.shutdown().unwrap();

    for (a, b) in batch.outcomes.iter().zip(served.iter()) {
        assert_eq!(a.id, b.id, "handles resolve in submission (= id) order");
        // Bit-identical Ok outcomes: serving must not touch numerics.
        assert_eq!(a.transform.m, b.transform.m, "job {} transform", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {} rmse", a.id);
        assert_eq!(a.iterations, b.iterations, "job {} iterations", a.id);
        assert_eq!(a.stop, b.stop);
    }
}

#[test]
fn latency_critical_doomed_jobs_shed_not_queued() {
    let p = pool(1, ServingConfig::default());
    let client = p.client();
    let doomed = job(0)
        .with_slo(SloClass::LatencyCritical)
        .with_deadline(Duration::ZERO);
    match client.try_submit(doomed).unwrap() {
        Submission::Shed(h) => {
            assert!(h.is_complete(), "shed handles resolve immediately");
            assert_eq!(h.class(), SloClass::LatencyCritical);
            let o = h.try_take().unwrap();
            assert_eq!(o.stop, StopReason::Shed);
            assert_eq!(o.lane, usize::MAX, "no lane ever saw the job");
            assert!(o.is_failed());
            assert!(o.error.as_deref().unwrap().contains("shed"));
            assert!(o.rmse.is_nan());
        }
        _ => panic!("a zero-budget latency-critical job must shed, not queue"),
    }
    let report = p.shutdown().unwrap();
    let lc = report
        .classes
        .iter()
        .find(|c| c.class == SloClass::LatencyCritical)
        .unwrap();
    assert_eq!(lc.submitted, 1);
    assert_eq!(lc.shed, 1);
    assert_eq!(lc.completed, 0);
    assert_eq!(report.lane_report.outcomes.len(), 0);
    // Sheds are deliberate refusals, not contained failures.
    assert_eq!(report.contained_failures(), 0);
}

#[test]
fn full_pool_parks_standard_and_sheds_latency_critical() {
    // max_in_flight = 0 admits nothing: deterministic backpressure.
    let p = pool(
        1,
        ServingConfig {
            stream_depth: 4,
            max_in_flight: 0,
        },
    );
    let client = p.client();
    match client.try_submit(job(0)).unwrap() {
        Submission::Parked(j) => assert_eq!(j.id, 0, "standard work is handed back intact"),
        _ => panic!("standard class must park under backpressure"),
    }
    match client.try_submit(job(1).with_slo(SloClass::BestEffort)).unwrap() {
        Submission::Parked(_) => {}
        _ => panic!("best-effort parks under backpressure too"),
    }
    match client.try_submit(job(2).with_slo(SloClass::LatencyCritical)).unwrap() {
        Submission::Shed(h) => {
            let o = h.wait();
            assert_eq!(o.stop, StopReason::Shed);
            assert!(o.error.as_deref().unwrap().contains("in-flight bound"));
        }
        _ => panic!("latency-critical must shed instead of parking"),
    }
    let report = p.shutdown().unwrap();
    assert_eq!(report.total_shed(), 1);
    assert_eq!(report.lane_report.outcomes.len(), 0);
}

#[test]
fn full_stream_gate_applies_per_client() {
    // stream_depth = 0: each client stream refuses its first submission,
    // while the one-shot path (pool-wide bound only) still serves.
    let p = pool(
        1,
        ServingConfig {
            stream_depth: 0,
            max_in_flight: 64,
        },
    );
    let client = p.client();
    assert!(matches!(
        client.try_submit(job(0)).unwrap(),
        Submission::Parked(_)
    ));
    let h = p.submit(job(1)).unwrap();
    assert!(!h.wait().is_failed());
    p.shutdown().unwrap();
}

#[test]
fn duplicate_in_flight_id_errors() {
    let p = pool(1, ServingConfig::default());
    // A heavy job keeps id 9 in flight while the duplicate arrives.
    let target = structured_cloud(4000, 7);
    let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, -0.05, 0.01));
    let source = target.transformed(&gt.inverse_rigid());
    let heavy = RegistrationJob::new(9, 0, source, target, Mat4::IDENTITY);
    let h = p.submit(heavy).unwrap();
    assert!(p.submit(job(9)).is_err(), "in-flight ids must be unique");
    assert!(!h.wait().is_failed());
    p.shutdown().unwrap();
}

#[test]
fn waker_fires_when_outcome_lands() {
    let p = pool(1, ServingConfig::default());
    let h = p.submit(job(3)).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    h.set_waker(move || tx.send(()).unwrap());
    rx.recv_timeout(Duration::from_secs(60)).expect("waker fired");
    assert!(h.is_complete());
    assert!(h.try_take().unwrap().rmse.is_finite());
    p.shutdown().unwrap();
}

#[test]
fn parked_work_retries_to_completion() {
    let p = pool(
        2,
        ServingConfig {
            stream_depth: 1,
            max_in_flight: 64,
        },
    );
    let client = p.client();
    let mut handles = Vec::new();
    for k in 0..6 {
        let mut j = job(k);
        loop {
            match client.try_submit(j).unwrap() {
                Submission::Accepted(h) => {
                    handles.push(h);
                    break;
                }
                Submission::Shed(_) => unreachable!("standard class never sheds"),
                Submission::Parked(back) => {
                    j = back;
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }
    for h in handles {
        assert!(!h.wait().is_failed());
    }
    let report = p.shutdown().unwrap();
    assert_eq!(report.lane_report.outcomes.len(), 6);
    assert_eq!(report.total_shed(), 0);
}

#[test]
fn submit_after_shutdown_errors() {
    let p = pool(1, ServingConfig::default());
    let client = p.client();
    p.shutdown().unwrap();
    assert!(client.try_submit(job(1)).is_err(), "closed pool refuses work");
}
