//! Bench: pool-wide residency coordination — four distinct maps whose
//! jobs interleave A,B,C,D,A,B,… (a fleet of vehicles spread over four
//! submaps, multiplexed through one accelerator pool). A single lane
//! with two residency slots thrashes: the LRU set never holds the next
//! map, so every job re-uploads (and rebuilds the kd-tree). Two
//! coordinated lanes with the *same* per-backend capacity cover all
//! four maps: the dispatcher routes each cold key to a lane with a free
//! residency slot before any warm lane evicts, so uploads collapse to
//! roughly one per map and evictions to ~0 — same transforms,
//! bit-identical.
//!
//!   cargo bench --bench residency_coordination
//!   FPPS_BENCH_SCANS=64 cargo bench --bench residency_coordination

use fpps::coordinator::{run_registration_batch, LaneIcpConfig, LaneReport, RegistrationJob};
use fpps::fpps_api::KdTreeCpuBackend;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::report::Table;
use fpps::rng::Pcg32;
use std::sync::Arc;
use std::time::Instant;

const MAPS: usize = 4;
const SLOTS: usize = 2; // per-backend residency — half the map count

fn map_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-20.0, 20.0), rng.range(-20.0, 20.0), 0.0]),
            1 => c.push([rng.range(-20.0, 20.0), 20.0, rng.range(0.0, 6.0)]),
            _ => c.push([-20.0, rng.range(-20.0, 20.0), rng.range(0.0, 6.0)]),
        }
    }
    c
}

fn build_jobs(maps: &[Arc<PointCloud>], scans: usize) -> Vec<RegistrationJob> {
    (0..scans as u64)
        .map(|k| {
            let map = &maps[(k as usize) % MAPS];
            let mut rng = Pcg32::new(3000 + k);
            let gt = Mat4::from_rt(
                Mat3::rot_z(0.008 * (k as f64 + 1.0)),
                Vec3::new(0.08 + 0.01 * k as f64, -0.04, 0.0),
            );
            let mut s = map.transformed(&gt.inverse_rigid());
            s.add_noise(0.01, &mut rng);
            RegistrationJob::new(
                k,
                0,
                s.random_sample(1024, &mut rng),
                Arc::clone(map),
                Mat4::IDENTITY,
            )
        })
        .collect()
}

fn run(maps: &[Arc<PointCloud>], scans: usize, lanes: usize) -> (LaneReport, f64) {
    let t0 = Instant::now();
    let report = run_registration_batch(
        build_jobs(maps, scans),
        lanes,
        8,
        LaneIcpConfig::default(),
        |_| Ok(KdTreeCpuBackend::with_residency_slots(SLOTS)),
    )
    .expect("lane pool");
    (report, t0.elapsed().as_secs_f64() * 1e3)
}

fn tally(r: &LaneReport) -> (usize, usize, usize) {
    (
        r.lanes.iter().map(|l| l.target_uploads).sum(),
        r.lanes.iter().map(|l| l.target_hits).sum(),
        r.lanes.iter().map(|l| l.target_evictions).sum(),
    )
}

fn main() {
    let scans: usize = std::env::var("FPPS_BENCH_SCANS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16)
        .max(MAPS);
    let maps: Vec<Arc<PointCloud>> = (0..MAPS as u64)
        .map(|k| Arc::new(map_cloud(8192, 2030 + k)))
        .collect();
    println!(
        "residency coordination: {scans} scans round-robin over {MAPS} x {}-point maps, \
         kdtree-cpu backends with {SLOTS} residency slots each\n",
        maps[0].len()
    );

    // Single lane: 2 slots against 4 alternating maps — guaranteed
    // thrash, the baseline the coordinator exists to beat.
    let (single, single_ms) = run(&maps, scans, 1);
    let (su, sh, se) = tally(&single);

    // Two coordinated lanes: pool capacity = maps, so free-slot routing
    // settles each map onto a lane and the ping-pong turns into hits.
    let lanes = 2;
    let (pool, pool_ms) = run(&maps, scans, lanes);
    let (pu, ph, pe) = tally(&pool);

    // Residency coordination is scheduling, not numerics: bit-identical.
    for (a, b) in single.outcomes.iter().zip(pool.outcomes.iter()) {
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
    }

    let mut t = Table::new("single lane (thrash) vs coordinated pool (same results)")
        .header(&["mode", "uploads", "hits", "evictions", "wall (ms)"]);
    for (mode, u, h, e, ms) in [
        ("1 lane, 2 slots", su, sh, se, single_ms),
        ("2 lanes, 2 slots each", pu, ph, pe, pool_ms),
    ] {
        t.row(vec![
            mode.to_string(),
            u.to_string(),
            h.to_string(),
            e.to_string(),
            format!("{ms:.1}"),
        ]);
    }
    t.print();
    pool.lane_table("\nPer-lane breakdown (coordinated pool)").print();

    println!(
        "\nuploads {su} -> {pu}, evictions {se} -> {pe} \
         ({scans} scans, {MAPS} maps, pool capacity {} slots)",
        lanes * SLOTS
    );

    // The single lane must re-upload every scan (2 slots can never hold
    // the next of 4 round-robin maps); the pool must do strictly better
    // however completions interleave (its floor — one upload per map,
    // maps x lanes under steals — shows in the table above).
    assert_eq!(su, scans, "1 lane, 2/4 maps resident: upload per scan");
    assert_eq!(su + sh, scans);
    assert!(
        pu < su,
        "coordinated pool ({pu} uploads) must beat the thrashing lane ({su})"
    );
    assert_eq!(pu + ph, scans, "every job either uploads or hits");

    // Machine-readable results for CI trend tracking: one JSON object,
    // written to the path named by FPPS_BENCH_JSON (hand-rolled — the
    // crate deliberately has no serde dependency).
    if let Ok(path) = std::env::var("FPPS_BENCH_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"residency_coordination\",\n  \"scans\": {scans},\n  \
             \"maps\": {MAPS},\n  \"slots_per_backend\": {SLOTS},\n  \"pool_lanes\": {lanes},\n  \
             \"single\": {{\"uploads\": {su}, \"hits\": {sh}, \"evictions\": {se}, \
             \"wall_ms\": {single_ms:.3}}},\n  \
             \"pool\": {{\"uploads\": {pu}, \"hits\": {ph}, \"evictions\": {pe}, \
             \"wall_ms\": {pool_ms:.3}}}\n}}\n"
        );
        std::fs::write(&path, json).expect("write FPPS_BENCH_JSON");
        println!("wrote bench results to {path}");
    }
    println!("residency_coordination bench complete");
}
