//! Bench: zero-copy data plane — allocations per job and steady-state
//! throughput. A counting global allocator measures two regions:
//!
//! * **engine hot path** — a warm [`FppsIcp`] serving repeated jobs
//!   from pooled staging and recycled scratch. The tentpole invariant
//!   is asserted, not just reported: **0 heap allocations per job**.
//! * **end-to-end lane pool** — the same jobs through
//!   [`run_registration_batch`]: SPSC rings + `Arc` payloads keep the
//!   data plane allocation-free, so what remains is the mpsc *control
//!   plane* (outcome/feedback events, a few small nodes per job),
//!   reported as allocations/job next to throughput.
//!
//! Lane-count bit-identity is asserted along the way (the rings and
//! the pool are plumbing, never numerics).
//!
//!   cargo bench --bench data_plane
//!   FPPS_BENCH_SCANS=64 cargo bench --bench data_plane   # longer run
//!   FPPS_BENCH_JSON=BENCH_data_plane.json cargo bench --bench data_plane

use fpps::alloc_counter::{snapshot, CountingAlloc};
use fpps::coordinator::{run_registration_batch, LaneIcpConfig, RegistrationJob};
use fpps::fpps_api::{FppsIcp, KdTreeCpuBackend, KernelBackend};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::report::Table;
use fpps::rng::Pcg32;
use std::sync::Arc;
use std::time::Instant;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn map_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-20.0, 20.0), rng.range(-20.0, 20.0), 0.0]),
            1 => c.push([rng.range(-20.0, 20.0), 20.0, rng.range(0.0, 6.0)]),
            _ => c.push([-20.0, rng.range(-20.0, 20.0), rng.range(0.0, 6.0)]),
        }
    }
    c
}

/// Warm engine serving `jobs` identical-target scans: returns
/// (allocations over the measured span, wall ms).
fn engine_span<B: KernelBackend>(
    icp: &mut FppsIcp<B>,
    source: &Arc<PointCloud>,
    target: &Arc<PointCloud>,
    jobs: usize,
) -> (u64, f64) {
    let run = |icp: &mut FppsIcp<B>| {
        icp.set_input_source(Arc::clone(source));
        icp.set_input_target(Arc::clone(target));
        let mut res = icp.align().expect("align");
        icp.recycle_stats(std::mem::take(&mut res.stats));
    };
    for _ in 0..3 {
        run(icp); // warm the pool, scratch, mirrors, stat buffer
    }
    let before = snapshot();
    let t0 = Instant::now();
    for _ in 0..jobs {
        run(icp);
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    (before.delta(&snapshot()).allocations, wall_ms)
}

fn build_jobs(map: &Arc<PointCloud>, scans: usize) -> Vec<RegistrationJob> {
    (0..scans as u64)
        .map(|k| {
            let mut rng = Pcg32::new(4000 + k);
            let gt = Mat4::from_rt(
                Mat3::rot_z(0.01 * (k as f64 + 1.0)),
                Vec3::new(0.08 + 0.01 * k as f64, -0.04, 0.0),
            );
            let mut s = map.transformed(&gt.inverse_rigid());
            s.add_noise(0.01, &mut rng);
            RegistrationJob::new(
                k,
                0,
                s.random_sample(512, &mut rng),
                Arc::clone(map),
                Mat4::IDENTITY,
            )
        })
        .collect()
}

fn main() {
    let scans: usize = std::env::var("FPPS_BENCH_SCANS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32)
        .max(2);
    let map = Arc::new(map_cloud(4096, 2040));
    println!(
        "data plane: engine hot path + lane pool over a {}-point map, \
         {scans} pool scans\n",
        map.len()
    );

    // Engine hot path: the zero-allocation claim, per backend.
    let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, -0.05, 0.0));
    let source = Arc::new(map.transformed(&gt.inverse_rigid()).random_sample(
        512,
        &mut Pcg32::new(2041),
    ));
    let engine_jobs = 100;
    let mut sim = FppsIcp::native_sim();
    let (sim_allocs, sim_ms) = engine_span(&mut sim, &source, &map, engine_jobs);
    let mut kd = FppsIcp::kdtree_cpu();
    let (kd_allocs, kd_ms) = engine_span(&mut kd, &source, &map, engine_jobs);
    assert_eq!(
        (sim_allocs, kd_allocs),
        (0, 0),
        "steady-state engine path must be allocation-free"
    );

    // End-to-end pool: one lane vs two, same jobs, bit-identical.
    let lanes = 2;
    let jobs_single = build_jobs(&map, scans);
    let jobs_pool = build_jobs(&map, scans);
    let before = snapshot();
    let t0 = Instant::now();
    let single = run_registration_batch(jobs_single, 1, 8, LaneIcpConfig::default(), |_| {
        Ok(KdTreeCpuBackend::new())
    })
    .expect("single lane");
    let single_ms = t0.elapsed().as_secs_f64() * 1e3;
    let single_allocs = before.delta(&snapshot()).allocations;
    let before = snapshot();
    let t0 = Instant::now();
    let pool = run_registration_batch(jobs_pool, lanes, 8, LaneIcpConfig::default(), |_| {
        Ok(KdTreeCpuBackend::new())
    })
    .expect("lane pool");
    let pool_ms = t0.elapsed().as_secs_f64() * 1e3;
    let pool_allocs = before.delta(&snapshot()).allocations;

    // Rings and routing are plumbing, never numerics.
    for (a, b) in single.outcomes.iter().zip(pool.outcomes.iter()) {
        assert_eq!(a.transform.m, b.transform.m, "job {}", a.id);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits(), "job {}", a.id);
    }
    let failed = single.failed_jobs() + pool.failed_jobs();
    assert_eq!(failed, 0, "no contained failures in a clean bench run");

    let per = |allocs: u64, jobs: usize| allocs as f64 / jobs as f64;
    let rate = |jobs: usize, ms: f64| jobs as f64 / (ms / 1e3).max(1e-9);
    let mut t = Table::new("allocations/job and throughput (steady state)")
        .header(&["region", "allocs/job", "jobs/s"]);
    for (region, a, j, ms) in [
        ("engine hot path (native-sim)", sim_allocs, engine_jobs, sim_ms),
        ("engine hot path (kdtree-cpu)", kd_allocs, engine_jobs, kd_ms),
        ("pool end-to-end (1 lane)", single_allocs, scans, single_ms),
        ("pool end-to-end (2 lanes)", pool_allocs, scans, pool_ms),
    ] {
        t.row(vec![
            region.to_string(),
            format!("{:.1}", per(a, j)),
            format!("{:.1}", rate(j, ms)),
        ]);
    }
    t.print();
    println!(
        "\nengine data plane: 0 allocations/job ({engine_jobs} jobs/backend); \
         pool control plane: {:.1} allocations/job end-to-end",
        per(pool_allocs, scans)
    );

    // Machine-readable results for CI trend tracking (hand-rolled JSON;
    // the crate deliberately has no serde dependency).
    if let Ok(path) = std::env::var("FPPS_BENCH_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"data_plane\",\n  \"engine_jobs\": {engine_jobs},\n  \
             \"pool_scans\": {scans},\n  \"pool_lanes\": {lanes},\n  \
             \"engine_native_sim\": {{\"allocs_per_job\": {:.3}, \"jobs_per_s\": {:.1}}},\n  \
             \"engine_kdtree\": {{\"allocs_per_job\": {:.3}, \"jobs_per_s\": {:.1}}},\n  \
             \"pool_single\": {{\"allocs_per_job\": {:.3}, \"jobs_per_s\": {:.1}}},\n  \
             \"pool\": {{\"allocs_per_job\": {:.3}, \"jobs_per_s\": {:.1}}}\n}}\n",
            per(sim_allocs, engine_jobs),
            rate(engine_jobs, sim_ms),
            per(kd_allocs, engine_jobs),
            rate(engine_jobs, kd_ms),
            per(single_allocs, scans),
            rate(scans, single_ms),
            per(pool_allocs, scans),
            rate(scans, pool_ms),
        );
        std::fs::write(&path, json).expect("write FPPS_BENCH_JSON");
        println!("wrote bench results to {path}");
    }
    println!("data_plane bench complete");
}
