//! Bench: PJRT runtime micro-benchmarks — the host↔device interface
//! costs of Fig. 2 on this CPU stand-in.
//!
//! Measures, per artifact variant: literal upload cost, execute wall
//! time, and steps/second, plus the NativeSim mirror for scale. These
//! are the numbers behind the §Perf L3 iteration log in EXPERIMENTS.md.
//! (PJRT-CPU wall time is the *functional* cost of simulating the
//! kernel, not an FPGA estimate — hwmodel/pipesim own the timing story.)
//!
//!   cargo bench --bench runtime_micro

use fpps::fpps_api::{KernelBackend, NativeSimBackend};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::report::Table;
use fpps::rng::Pcg32;
use fpps::runtime::Engine;
use std::path::Path;
use std::time::Instant;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let t0 = Instant::now();
    let mut engine = match Engine::load(dir) {
        Ok(e) => e,
        Err(e) => {
            println!("engine unavailable ({e:#}); skipping");
            return;
        }
    };
    println!(
        "engine load+compile (hardwareInitialize): {:.0} ms, platform {}\n",
        t0.elapsed().as_secs_f64() * 1e3,
        engine.platform()
    );

    let t = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, 0.0, 0.0));
    let mut table = Table::new("PJRT execute cost per variant").header(&[
        "variant",
        "upload (ms)",
        "execute (ms)",
        "steps/s",
        "native-sim (ms)",
    ]);

    let variants: Vec<(usize, String, usize, usize, usize, usize)> = engine
        .manifest()
        .variants
        .iter()
        .enumerate()
        .map(|(i, v)| (i, v.name.clone(), v.n, v.m, v.block_n, v.block_m))
        .collect();

    for (vi, name, n, m, bn, bm) in variants {
        let mut rng = Pcg32::new(vi as u64 + 1);
        let src: Vec<f32> = (0..n * 3).map(|_| rng.range(-10.0, 10.0)).collect();
        let tgt: Vec<f32> = (0..m * 3).map(|_| rng.range(-10.0, 10.0)).collect();
        let smask = vec![1f32; n];
        let tmask = vec![1f32; m];

        // Warm up once, then time a few reps.
        let _ = engine
            .execute_step(vi, &src, &tgt, &smask, &tmask, &t, 1e30)
            .expect("warmup");
        let reps = if m >= 16_384 { 3 } else { 10 };
        let mut upload_ms = 0.0;
        let mut exec_ms = 0.0;
        for _ in 0..reps {
            let (_, timing) = engine
                .execute_step(vi, &src, &tgt, &smask, &tmask, &t, 1e30)
                .expect("step");
            upload_ms += timing.upload.as_secs_f64() * 1e3;
            exec_ms += timing.execute.as_secs_f64() * 1e3;
        }
        upload_ms /= reps as f64;
        exec_ms /= reps as f64;

        // NativeSim for the same variant shape.
        let mut sim = NativeSimBackend::with_blocks(bn, bm);
        let t0 = Instant::now();
        let _ = sim
            .icp_step(&src, &tgt, &smask, &tmask, &t, 1e30)
            .expect("sim");
        let sim_ms = t0.elapsed().as_secs_f64() * 1e3;

        table.row(vec![
            name,
            format!("{upload_ms:.2}"),
            format!("{exec_ms:.1}"),
            format!("{:.2}", 1e3 / (upload_ms + exec_ms)),
            format!("{sim_ms:.1}"),
        ]);
    }
    table.print();
    println!("\ntotal engine executions: {}", engine.executions);
    println!("runtime_micro bench complete");
}
