//! Bench: voxel-grid vs kd-tree bounded-NN throughput at city scale.
//!
//! Builds uniform-density synthetic maps at growing tiers (10k → 1M
//! points by default), then answers the same bounded nearest-neighbour
//! queries (`max_dist = 2 m`) through both indexes:
//!
//! * [`fpps::kdtree::OwnedKdTree::nearest_within_sq`] — the exact
//!   baseline every backend used before ISSUE 8;
//! * [`fpps::voxelgrid::VoxelGrid::nearest`] with a covering budget
//!   (`cell = 1 m`, `max_ring = 2` ≥ the query radius), so both answer
//!   every query identically — the speedup is pure data-structure
//!   locality, not accuracy loss. Identity is asserted on a sample.
//!
//! The tentpole claim is asserted, not just reported: at the largest
//! tier the grid must deliver **≥ 2×** the kd-tree query throughput.
//!
//!   cargo bench --bench nn_scaling
//!   FPPS_BENCH_NN_MAX=100000 cargo bench --bench nn_scaling  # smaller cap
//!   FPPS_BENCH_JSON=BENCH_nn_scaling.json cargo bench --bench nn_scaling

use fpps::kdtree::OwnedKdTree;
use fpps::pointcloud::PointCloud;
use fpps::report::Table;
use fpps::rng::Pcg32;
use fpps::voxelgrid::VoxelGrid;
use std::time::Instant;

const MAX_DIST_SQ: f32 = 4.0; // 2 m correspondence radius
const CELL_SIZE: f32 = 1.0;
const MAX_RING: usize = 2; // 2 × 1 m ≥ 2 m: covering budget, exact answers
const QUERIES: usize = 20_000;

/// Uniform map at ~1 point/m³ — the extent grows with the point count,
/// like a city map does, instead of packing a fixed box ever denser.
fn city_cloud(n: usize, seed: u64) -> PointCloud {
    let side = (n as f32).cbrt();
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for _ in 0..n {
        c.push([
            rng.range(0.0, side),
            rng.range(0.0, side),
            rng.range(0.0, side),
        ]);
    }
    c
}

/// Scan-like queries: map points jittered by up to ±0.3 m, so a true
/// neighbour exists within the radius for every query.
fn queries_near(cloud: &PointCloud, count: usize, seed: u64) -> Vec<[f32; 3]> {
    let mut rng = Pcg32::new(seed);
    (0..count)
        .map(|_| {
            let i = (rng.range(0.0, cloud.len() as f32) as usize).min(cloud.len() - 1);
            let p = cloud.get(i);
            [
                p[0] + rng.range(-0.3, 0.3),
                p[1] + rng.range(-0.3, 0.3),
                p[2] + rng.range(-0.3, 0.3),
            ]
        })
        .collect()
}

struct TierResult {
    points: usize,
    kd_build_ms: f64,
    kd_qps: f64,
    grid_build_ms: f64,
    grid_qps: f64,
    grid_cells: usize,
}

fn run_tier(points: usize, seed: u64) -> TierResult {
    let cloud = city_cloud(points, seed);
    let queries = queries_near(&cloud, QUERIES, seed + 1);

    let t0 = Instant::now();
    let tree = OwnedKdTree::build(cloud);
    let kd_build_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t0 = Instant::now();
    let grid = VoxelGrid::build(tree.cloud(), CELL_SIZE, MAX_RING);
    let grid_build_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Covering budget ⇒ identical bounded-NN answers; spot-check before
    // timing so the throughput numbers compare equal work.
    for q in queries.iter().take(1000) {
        let a = tree.nearest_within_sq(*q, MAX_DIST_SQ);
        let b = grid.nearest(tree.cloud(), *q, MAX_DIST_SQ);
        match (a, b) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(
                    a.dist_sq.to_bits(),
                    b.dist_sq.to_bits(),
                    "covering-budget grid must answer exactly"
                );
            }
            (a, b) => panic!("index disagreement: kd {a:?} vs grid {b:?}"),
        }
    }

    // Checksums keep the query loops from being optimized away.
    let time_qps = |f: &dyn Fn([f32; 3]) -> f32| {
        let t0 = Instant::now();
        let mut sum = 0.0f64;
        for q in &queries {
            sum += f(*q) as f64;
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        assert!(sum.is_finite());
        queries.len() as f64 / secs
    };
    let kd_qps = time_qps(&|q| {
        tree.nearest_within_sq(q, MAX_DIST_SQ)
            .map_or(0.0, |n| n.dist_sq)
    });
    let grid_qps = time_qps(&|q| {
        grid.nearest(tree.cloud(), q, MAX_DIST_SQ)
            .map_or(0.0, |n| n.dist_sq)
    });

    TierResult {
        points,
        kd_build_ms,
        kd_qps,
        grid_build_ms,
        grid_qps,
        grid_cells: grid.occupied_cells(),
    }
}

fn main() {
    let max_points: usize = std::env::var("FPPS_BENCH_NN_MAX")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000)
        .max(10_000);
    let tiers: Vec<usize> = [10_000usize, 100_000, 1_000_000]
        .into_iter()
        .filter(|&n| n <= max_points)
        .collect();
    println!(
        "nn scaling: bounded NN (r = {} m) through kd-tree vs voxel grid \
         (cell {CELL_SIZE} m, ring {MAX_RING}), {QUERIES} queries/tier\n",
        MAX_DIST_SQ.sqrt()
    );

    let results: Vec<TierResult> = tiers
        .iter()
        .enumerate()
        .map(|(i, &n)| run_tier(n, 9000 + i as u64))
        .collect();

    let mut t = Table::new("bounded-NN throughput by map size").header(&[
        "points",
        "kd build ms",
        "kd kq/s",
        "grid build ms",
        "grid kq/s",
        "speedup",
        "cells",
    ]);
    for r in &results {
        t.row(vec![
            format!("{}", r.points),
            format!("{:.1}", r.kd_build_ms),
            format!("{:.1}", r.kd_qps / 1e3),
            format!("{:.1}", r.grid_build_ms),
            format!("{:.1}", r.grid_qps / 1e3),
            format!("{:.2}x", r.grid_qps / r.kd_qps),
            format!("{}", r.grid_cells),
        ]);
    }
    t.print();

    let top = results.last().expect("at least one tier");
    let speedup = top.grid_qps / top.kd_qps;
    println!(
        "\nlargest tier ({} points): grid {:.2}x kd-tree query throughput",
        top.points, speedup
    );
    if top.points >= 1_000_000 {
        assert!(
            speedup >= 2.0,
            "acceptance: grid must be >= 2x kd-tree NN throughput at the \
             1M tier, measured {speedup:.2}x"
        );
    }

    if let Ok(path) = std::env::var("FPPS_BENCH_JSON") {
        let tier_objs: Vec<String> = results
            .iter()
            .map(|r| {
                format!(
                    "    {{\"points\": {}, \
                     \"kdtree\": {{\"build_ms\": {:.1}, \"queries_per_s\": {:.0}}}, \
                     \"grid\": {{\"build_ms\": {:.1}, \"queries_per_s\": {:.0}}}, \
                     \"speedup\": {:.3}}}",
                    r.points,
                    r.kd_build_ms,
                    r.kd_qps,
                    r.grid_build_ms,
                    r.grid_qps,
                    r.grid_qps / r.kd_qps
                )
            })
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"nn_scaling\",\n  \"queries\": {QUERIES},\n  \
             \"max_dist\": {:.1},\n  \"cell_size\": {CELL_SIZE},\n  \
             \"max_ring\": {MAX_RING},\n  \"tiers\": [\n{}\n  ]\n}}\n",
            MAX_DIST_SQ.sqrt(),
            tier_objs.join(",\n")
        );
        std::fs::write(&path, json).expect("write FPPS_BENCH_JSON");
        println!("wrote bench results to {path}");
    }
    println!("nn_scaling bench complete");
}
