//! Bench: **Fig. 3** — the four-stage streaming NN pipeline, validated
//! cycle-by-cycle.
//!
//! Runs the discrete-event simulator over the paper-scale workload and
//! a parameter sweep, checking (a) the closed-form latency model in
//! `hwmodel::latency` matches the simulated pipeline within 5%, and
//! (b) the stage-utilisation story of the paper (distance stage ~100%
//! busy, everything else hidden behind it).
//!
//!   cargo bench --bench pipesim_fig3

use fpps::hwmodel::{latency, AcceleratorConfig};
use fpps::pipesim::simulate;
use fpps::report::Table;

fn main() {
    let cfg = AcceleratorConfig::default();

    println!("Fig. 3 pipeline: paper-scale pass (4096 x 131072)\n");
    let sim = simulate(&cfg, 4096, 131_072);
    let model = latency::nn_search_cycles(&cfg, 4096, 131_072);
    println!(
        "simulated {} cycles = {:.2} ms @ {} MHz   (closed form: {} cycles, {:+.2}%)",
        sim.total_cycles,
        sim.seconds(&cfg) * 1e3,
        cfg.clock_mhz,
        model,
        100.0 * (sim.total_cycles as f64 - model as f64) / model as f64
    );
    let names = ["read", "distance", "compare", "accumulate"];
    let mut t = Table::new("\nStage occupancy (task-level pipelining)").header(&[
        "stage", "busy", "stall", "idle",
    ]);
    for (name, s) in names.iter().zip(sim.stages.iter()) {
        t.row(vec![
            name.to_string(),
            format!("{:.1}%", 100.0 * s.busy_cycles as f64 / sim.total_cycles as f64),
            format!("{:.1}%", 100.0 * s.stall_cycles as f64 / sim.total_cycles as f64),
            format!("{:.1}%", 100.0 * s.idle_cycles as f64 / sim.total_cycles as f64),
        ]);
    }
    t.print();
    println!(
        "FIFO max occupancy: rd->dist {} / dist->cmp {} / cmp->acc {}",
        sim.fifo_max_occupancy[0], sim.fifo_max_occupancy[1], sim.fifo_max_occupancy[2]
    );

    // Sweep: sim vs model across sizes and PE arrays.
    let mut sweep = Table::new("\nSim vs closed-form across configurations").header(&[
        "PE array",
        "N x M",
        "sim cycles",
        "model cycles",
        "err",
        "ms @300MHz",
    ]);
    for (rows, cols) in [(8usize, 16usize), (8, 8), (16, 16), (4, 32)] {
        for (n, m) in [(1024usize, 16_384usize), (4096, 65_536)] {
            let c = AcceleratorConfig {
                pe_rows: rows,
                pe_cols: cols,
                ..Default::default()
            };
            let s = simulate(&c, n, m);
            let f = latency::nn_search_cycles(&c, n, m);
            sweep.row(vec![
                format!("{rows}x{cols}"),
                format!("{n}x{m}"),
                s.total_cycles.to_string(),
                f.to_string(),
                format!(
                    "{:+.2}%",
                    100.0 * (s.total_cycles as f64 - f as f64) / f as f64
                ),
                format!("{:.2}", s.seconds(&c) * 1e3),
            ]);
        }
    }
    sweep.print();

    let dist_util =
        sim.stages[1].busy_cycles as f64 / sim.total_cycles as f64;
    assert!(dist_util > 0.95, "distance stage should dominate");
    println!(
        "\ndistance stage utilisation {:.1}% — the four-stage overlap the paper\n\
         describes: read/compare/accumulate ride entirely behind the PE array.",
        dist_util * 100.0
    );
    println!("pipesim_fig3 bench complete");
}
