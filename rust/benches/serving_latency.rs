//! Bench: serving-tier end-to-end latency per SLO class.
//!
//! A population of client streams (cycling through the three
//! [`SloClass`]es) submits canonical frame pairs through non-blocking
//! submission handles; the report is each class's p50/p99/p999
//! end-to-end latency (submission to completion, queue wait included)
//! plus aggregate throughput.
//!
//! The run shape is deterministic by construction: every client submits
//! exactly its stream depth, no deadlines are set, and the pool-wide
//! in-flight bound exceeds the job count — so nothing can park or shed
//! and the per-class submitted/ok counts are exact contract keys for
//! the CI `bench_diff` gate (latency and throughput keys are
//! machine-dependent and stay out of the committed baseline).
//!
//!   cargo bench --bench serving_latency
//!   FPPS_BENCH_CLIENTS=256 cargo bench --bench serving_latency
//!   FPPS_BENCH_JSON=BENCH_serving.json cargo bench --bench serving_latency

use fpps::coordinator::{
    LaneIcpConfig, RegistrationJob, ServingConfig, ServingPool, SloClass, Submission,
    SupervisorConfig,
};
use fpps::fpps_api::NativeSimBackend;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

const JOBS_PER_CLIENT: usize = 4;
const STREAM_DEPTH: usize = 4; // == JOBS_PER_CLIENT: no stream ever fills
const LANES: usize = 2;
const PAIRS: usize = 32;
const POINTS: usize = 320;

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

fn main() {
    let clients: usize = std::env::var("FPPS_BENCH_CLIENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
        .max(1);
    let jobs = clients * JOBS_PER_CLIENT;
    println!(
        "serving latency: {clients} clients x {JOBS_PER_CLIENT} jobs over {LANES} lane(s), \
         stream depth {STREAM_DEPTH}, native-sim backend\n"
    );

    let canonical: Vec<(u64, Arc<PointCloud>, Arc<PointCloud>)> = (0..PAIRS)
        .map(|k| {
            let target = Arc::new(structured_cloud(POINTS, 100 + k as u64));
            let gt = Mat4::from_rt(
                Mat3::rot_z(0.005 * (k as f64 + 1.0)),
                Vec3::new(0.05 + 0.01 * (k % 8) as f64, -0.03, 0.01),
            );
            let source = Arc::new(target.transformed(&gt.inverse_rigid()));
            (k as u64, source, target)
        })
        .collect();

    let pool = ServingPool::start(
        LANES,
        4,
        LaneIcpConfig::default(),
        SupervisorConfig::default(),
        ServingConfig {
            stream_depth: STREAM_DEPTH,
            max_in_flight: jobs.max(1024),
        },
        |_lane, _tier| Ok(NativeSimBackend::new()),
    )
    .expect("serving pool start");

    let streams: Vec<_> = (0..clients).map(|_| pool.client()).collect();
    let mut handles = Vec::with_capacity(jobs);
    for (c, stream) in streams.iter().enumerate() {
        let class = SloClass::all()[c % 3];
        for k in 0..JOBS_PER_CLIENT {
            let (key, source, target) = &canonical[(c + k) % PAIRS];
            let mut job = RegistrationJob::new_keyed(
                (c * JOBS_PER_CLIENT + k) as u64,
                c,
                Arc::clone(source),
                Arc::clone(target),
                *key,
                Mat4::IDENTITY,
            )
            .with_slo(class);
            // Defensive park-retry; by construction nothing parks here.
            loop {
                match stream.try_submit(job).expect("submit") {
                    Submission::Accepted(h) | Submission::Shed(h) => {
                        handles.push(h);
                        break;
                    }
                    Submission::Parked(back) => {
                        job = back;
                        std::thread::sleep(Duration::from_micros(50));
                    }
                }
            }
        }
    }

    let report = pool.shutdown().expect("serving pool shutdown");
    assert!(
        handles.iter().all(|h| h.is_complete()),
        "shutdown resolves every handle"
    );
    assert_eq!(report.lane_report.outcomes.len(), jobs, "work conservation");
    assert_eq!(report.total_shed(), 0, "nothing can shed in this shape");
    assert_eq!(report.contained_failures(), 0, "no contained failures");

    report.class_table().print();
    report.lane_report.lane_table("\nPer-lane breakdown").print();
    println!(
        "\nserved {jobs} jobs in {:.1} s  ->  {:.1} jobs/s aggregate",
        report.lane_report.wall_ms / 1e3,
        report.lane_report.jobs_per_s()
    );

    if let Ok(path) = std::env::var("FPPS_BENCH_JSON") {
        let class_objs: Vec<String> = report
            .classes
            .iter()
            .map(|c| {
                format!(
                    "    \"{}\": {{\"submitted\": {}, \"completed\": {}, \"ok\": {}, \
                     \"shed\": {}, \"p50_ms\": {:.2}, \"p99_ms\": {:.2}, \"p999_ms\": {:.2}}}",
                    c.class.name(),
                    c.submitted,
                    c.completed,
                    c.ok,
                    c.shed,
                    c.latency.percentile_ms(50.0),
                    c.latency.percentile_ms(99.0),
                    c.latency.percentile_ms(99.9)
                )
            })
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"serving_latency\",\n  \"clients\": {clients},\n  \
             \"jobs_per_client\": {JOBS_PER_CLIENT},\n  \"jobs\": {jobs},\n  \
             \"lanes\": {LANES},\n  \"stream_depth\": {STREAM_DEPTH},\n  \
             \"shed_total\": {},\n  \"classes\": {{\n{}\n  }},\n  \
             \"jobs_per_s\": {:.2}\n}}\n",
            report.total_shed(),
            class_objs.join(",\n"),
            report.lane_report.jobs_per_s()
        );
        std::fs::write(&path, json).expect("write FPPS_BENCH_JSON");
        println!("wrote bench results to {path}");
    }
    println!("serving_latency bench complete");
}
