//! Bench: regenerate **Table III** (average RMSE comparison, meters) —
//! the CPU baseline vs the FPPS hybrid on all ten sequences.
//!
//! Claim under test: FPGA offload does not compromise registration
//! accuracy; per-sequence RMSE matches the CPU implementation within
//! ~0.01 m (the paper's seq-00 row differs more because the hybrid
//! samples 4096 source points — visible here too).
//!
//!   cargo bench --bench table3_rmse
//!   FPPS_BENCH_FRAMES=8 cargo bench --bench table3_rmse   # longer run
//!
//! Backend note: the FPPS side runs the NativeSim device mirror; the
//! integration suite (`cargo test --test integration`) proves NativeSim
//! ≡ AOT-artifact-on-PJRT to ≪1e-3 m, so the parity claim transfers.

use fpps::bench_support::{bench_frames, bench_sequence, run_cpu_baseline, AnyBackend};
use fpps::dataset::sequence_specs;
use fpps::report::Table;

fn main() {
    let frames = bench_frames();
    let mut backend = AnyBackend::sim();
    println!(
        "Table III reproduction: {} frames/sequence, FPPS backend = {}\n",
        frames,
        backend.name()
    );

    let mut t = Table::new("TABLE III: Average RMSE comparison (meter)").header(&[
        "Sequence",
        "CPU",
        "CPU+FPGA",
        "delta",
        "paper CPU",
        "paper CPU+FPGA",
    ]);
    let paper_cpu = [0.198, 0.417, 0.205, 0.218, 0.330, 0.197, f64::NAN, 0.178, 0.216, f64::NAN];
    let paper_fpga = [0.265, 0.422, 0.205, 0.218, 0.329, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN];

    let mut deltas = Vec::new();
    for (i, spec) in sequence_specs().into_iter().enumerate() {
        let seq = bench_sequence(spec, frames);
        let cpu = run_cpu_baseline(&seq, frames).expect("cpu baseline");
        let fpps = backend.run(&seq, frames).expect("fpps run");
        let delta = (cpu.mean_rmse - fpps.mean_rmse).abs();
        deltas.push(delta);
        let fmt = |v: f64| if v.is_nan() { "-".to_string() } else { format!("{v:.3}") };
        t.row(vec![
            seq.spec.name.to_string(),
            format!("{:.3}", cpu.mean_rmse),
            format!("{:.3}", fpps.mean_rmse),
            format!("{delta:.3}"),
            fmt(paper_cpu[i]),
            fmt(paper_fpga[i]),
        ]);
        eprintln!("  sequence {} done", seq.spec.name);
    }
    t.print();

    let max_delta = deltas.iter().cloned().fold(0.0f64, f64::max);
    let ok = deltas.iter().filter(|d| **d < 0.05).count();
    println!(
        "\nmax CPU-vs-FPPS delta: {max_delta:.3} m; {ok}/10 sequences within 0.05 m.\n\
         Paper claim: marginal variations within 0.01 m (except seq 00 at 0.067).\n\
         Differences here, as there, stem from the hybrid path sampling 4096\n\
         source points while the CPU baseline registers the full cloud."
    );
    println!("table3_rmse bench complete");
}
