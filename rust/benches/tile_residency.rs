//! Bench: tile-crossing residency — a two-map alternating workload
//! (A,B,A,B,…: the submap ping-pong of a vehicle tracking along a tile
//! boundary) on the kd-tree CPU backend, single-slot vs LRU multi-slot
//! residency. One slot re-uploads (and rebuilds the kd-tree) on every
//! map switch; with ≥ 2 slots each map uploads exactly once and every
//! further scan is a cache hit — same transforms, bit-identical. A lane
//! pool section shows the affinity dispatcher keeping the ping-pong
//! warm across lanes.
//!
//!   cargo bench --bench tile_residency
//!   FPPS_BENCH_SCANS=64 cargo bench --bench tile_residency   # longer run
//!   FPPS_BENCH_JSON=BENCH_tile_residency.json cargo bench --bench tile_residency

use fpps::coordinator::{run_registration_batch, LaneIcpConfig, RegistrationJob};
use fpps::fpps_api::{FppsIcp, KdTreeCpuBackend, KernelBackend};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::report::Table;
use fpps::rng::Pcg32;
use std::sync::Arc;
use std::time::Instant;

fn map_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-20.0, 20.0), rng.range(-20.0, 20.0), 0.0]),
            1 => c.push([rng.range(-20.0, 20.0), 20.0, rng.range(0.0, 6.0)]),
            _ => c.push([-20.0, rng.range(-20.0, 20.0), rng.range(0.0, 6.0)]),
        }
    }
    c
}

/// Alternating scans: scan k queries map A (k even) or map B (k odd).
fn ping_pong_scans(
    maps: &[Arc<PointCloud>; 2],
    scans: usize,
) -> Vec<(Arc<PointCloud>, PointCloud)> {
    (0..scans as u64)
        .map(|k| {
            let map = &maps[(k % 2) as usize];
            let mut rng = Pcg32::new(2000 + k);
            let gt = Mat4::from_rt(
                Mat3::rot_z(0.01 * (k as f64 + 1.0)),
                Vec3::new(0.1 + 0.01 * k as f64, -0.05, 0.0),
            );
            let mut s = map.transformed(&gt.inverse_rigid());
            s.add_noise(0.01, &mut rng);
            (Arc::clone(map), s.random_sample(2048, &mut rng))
        })
        .collect()
}

fn main() {
    // At least two scans: the assertions below describe a two-map
    // ping-pong, which needs one visit to each map.
    let scans: usize = std::env::var("FPPS_BENCH_SCANS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16)
        .max(2);
    let maps = [
        Arc::new(map_cloud(16_384, 2026)),
        Arc::new(map_cloud(16_384, 2027)),
    ];
    let workload = ping_pong_scans(&maps, scans);
    println!(
        "tile residency: {scans} scans ping-ponging across 2 x {}-point maps, \
         kdtree-cpu backend\n",
        maps[0].len()
    );

    // Single slot: every map switch re-uploads and rebuilds the index —
    // the pre-LRU behavior the tile-crossing workload thrashes.
    let t0 = Instant::now();
    let mut single = FppsIcp::with_backend(KdTreeCpuBackend::with_residency_slots(1));
    let mut single_results = Vec::new();
    for (map, src) in &workload {
        single.set_input_source(src.clone());
        single.set_input_target(Arc::clone(map));
        single_results.push(single.align().expect("single-slot align"));
    }
    let single_ms = t0.elapsed().as_secs_f64() * 1e3;
    let single_builds = single.backend().tree_builds();
    let (single_uploads, _, _) = single.target_cache_stats();

    // LRU residency (hwmodel default, ≥ 2 slots): both maps stay
    // resident, so the ping-pong costs two uploads total.
    let t0 = Instant::now();
    let mut multi = FppsIcp::kdtree_cpu();
    let slots = multi.backend().residency_slots();
    let mut multi_results = Vec::new();
    for (map, src) in &workload {
        multi.set_input_source(src.clone());
        multi.set_input_target(Arc::clone(map));
        multi_results.push(multi.align().expect("multi-slot align"));
    }
    let multi_ms = t0.elapsed().as_secs_f64() * 1e3;
    let multi_builds = multi.backend().tree_builds();
    let (multi_uploads, multi_hits, _) = multi.target_cache_stats();

    // Residency is a cache, not a numerics change: bit-identical.
    for (s, m) in single_results.iter().zip(multi_results.iter()) {
        assert_eq!(s.transformation.m, m.transformation.m);
        assert_eq!(s.rmse.to_bits(), m.rmse.to_bits());
    }

    let mut t = Table::new("single-slot vs LRU residency (same results, bit-identical)")
        .header(&["mode", "uploads", "kd builds", "total (ms)", "per-scan (ms)"]);
    let rows = [
        ("1 slot (thrash)", single_uploads, single_builds, single_ms),
        (
            "LRU slots (hwmodel)",
            multi_uploads,
            multi_builds,
            multi_ms,
        ),
    ];
    for (mode, uploads, builds, total) in rows {
        t.row(vec![
            mode.to_string(),
            uploads.to_string(),
            builds.to_string(),
            format!("{total:.1}"),
            format!("{:.2}", total / scans as f64),
        ]);
    }
    t.print();

    println!(
        "\nspeedup from multi-target residency: {:.2}x  (uploads {} -> {}, builds {} -> {}, \
         {slots} slots)",
        single_ms / multi_ms.max(1e-9),
        single_uploads,
        multi_uploads,
        single_builds,
        multi_builds
    );
    assert!(slots >= 2, "hwmodel budget must grant >= 2 slots");
    assert_eq!(multi_uploads, 2, "one upload per map with LRU residency");
    assert_eq!(multi_builds, 2, "one kd-tree build per map");
    assert_eq!(multi_hits as usize, scans - 2);
    assert_eq!(single_uploads as usize, scans, "one slot: upload per scan");

    // Lane-pool flavor: the affinity dispatcher mirrors the warm sets,
    // so pool-wide uploads stay bounded by maps x lanes.
    let lanes = 2;
    let jobs: Vec<RegistrationJob> = workload
        .iter()
        .enumerate()
        .map(|(k, (map, src))| {
            RegistrationJob::new(k as u64, k % 2, src.clone(), Arc::clone(map), Mat4::IDENTITY)
        })
        .collect();
    let report = run_registration_batch(jobs, lanes, 8, LaneIcpConfig::default(), |_| {
        Ok(KdTreeCpuBackend::new())
    })
    .expect("lane pool");
    report.lane_table("\nPer-lane breakdown (2 lanes)").print();
    let pool_uploads: usize = report.lanes.iter().map(|l| l.target_uploads).sum();
    let pool_hits: usize = report.lanes.iter().map(|l| l.target_hits).sum();
    println!(
        "\npool residency: {pool_uploads} upload(s) + {pool_hits} hit(s) over {lanes} lanes \
         ({scans} scans, 2 maps)"
    );
    assert!(
        pool_uploads <= 2 * lanes,
        "pool uploads {pool_uploads} exceed maps x lanes"
    );
    assert_eq!(pool_uploads + pool_hits, scans);

    if let Ok(path) = std::env::var("FPPS_BENCH_JSON") {
        // Deterministic contract keys: upload/build/hit counts follow
        // from the residency policy alone. Wall times and the speedup
        // are machine-dependent and stay out of the committed baseline
        // (the CI gate skips `_ms` and `speedup`).
        let json = format!(
            "{{\n  \"bench\": \"tile_residency\",\n  \"scans\": {scans},\n  \
             \"maps\": 2,\n  \"lanes\": {lanes},\n  \
             \"single\": {{\"uploads\": {single_uploads}, \"builds\": {single_builds}, \
             \"total_ms\": {single_ms:.1}}},\n  \
             \"multi\": {{\"uploads\": {multi_uploads}, \"builds\": {multi_builds}, \
             \"hits\": {multi_hits}, \"total_ms\": {multi_ms:.1}}},\n  \
             \"speedup\": {:.3},\n  \
             \"pool\": {{\"scans_served\": {}}}\n}}\n",
            single_ms / multi_ms.max(1e-9),
            pool_uploads + pool_hits
        );
        std::fs::write(&path, json).expect("write FPPS_BENCH_JSON");
        println!("wrote bench results to {path}");
    }
    println!("tile_residency bench complete");
}
