//! Bench: cross-frame target reuse — cached (resident map) vs.
//! fresh-upload alignment cost on the kd-tree CPU backend, where the
//! target upload includes an index build. With an unchanged map the
//! build is paid once, so the amortized per-scan cost converges to the
//! query-only cost; the "build share" column shows the kd-tree build
//! cost dropping to near zero for map reuse. The CPU baseline's
//! map-reuse path (`icp::align_with_tree`) is included for reference.
//!
//!   cargo bench --bench target_reuse
//!   FPPS_BENCH_SCANS=64 cargo bench --bench target_reuse   # longer run
//!   FPPS_BENCH_JSON=BENCH_target_reuse.json cargo bench --bench target_reuse

use fpps::fpps_api::FppsIcp;
use fpps::icp::{align_with_tree, IcpParams};
use fpps::kdtree::OwnedKdTree;
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::report::Table;
use fpps::rng::Pcg32;
use std::sync::Arc;
use std::time::Instant;

fn map_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-20.0, 20.0), rng.range(-20.0, 20.0), 0.0]),
            1 => c.push([rng.range(-20.0, 20.0), 20.0, rng.range(0.0, 6.0)]),
            _ => c.push([-20.0, rng.range(-20.0, 20.0), rng.range(0.0, 6.0)]),
        }
    }
    c
}

fn scan_sources(map: &PointCloud, scans: usize) -> Vec<(PointCloud, Mat4)> {
    (0..scans as u64)
        .map(|k| {
            let mut rng = Pcg32::new(1000 + k);
            let gt = Mat4::from_rt(
                Mat3::rot_z(0.01 * (k as f64 + 1.0)),
                Vec3::new(0.1 + 0.01 * k as f64, -0.05, 0.0),
            );
            let mut s = map.transformed(&gt.inverse_rigid());
            s.add_noise(0.01, &mut rng);
            (s.random_sample(2048, &mut rng), gt)
        })
        .collect()
}

fn main() {
    let scans: usize = std::env::var("FPPS_BENCH_SCANS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let map = Arc::new(map_cloud(16_384, 2026));
    let sources = scan_sources(&map, scans);
    println!(
        "target reuse: {scans} scans x {}-point map, kdtree-cpu backend\n",
        map.len()
    );

    // Fresh upload: a new session per scan — every align rebuilds the
    // kd-tree (what the pre-split begin() did implicitly).
    let t0 = Instant::now();
    let mut fresh_builds = 0;
    let mut fresh_results = Vec::new();
    for (s, _) in &sources {
        let mut icp = FppsIcp::kdtree_cpu();
        icp.set_input_source(s.clone());
        icp.set_input_target(Arc::clone(&map));
        fresh_results.push(icp.align().expect("fresh align"));
        fresh_builds += icp.backend().tree_builds();
    }
    let fresh_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Cached: one session, the map stays resident — one build total.
    let t0 = Instant::now();
    let mut icp = FppsIcp::kdtree_cpu();
    let mut cached_results = Vec::new();
    for (s, _) in &sources {
        icp.set_input_source(s.clone());
        icp.set_input_target(Arc::clone(&map));
        cached_results.push(icp.align().expect("cached align"));
    }
    let cached_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cached_builds = icp.backend().tree_builds();

    // CPU-baseline map reuse: prebuilt OwnedKdTree + align_with_tree.
    let t_build = Instant::now();
    let tree = OwnedKdTree::build((*map).clone());
    let build_ms = t_build.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    for (s, _) in &sources {
        let _ = align_with_tree(s, &tree, &Mat4::IDENTITY, &IcpParams::default());
    }
    let baseline_ms = t0.elapsed().as_secs_f64() * 1e3 + build_ms;

    // Cached and fresh must agree bit-for-bit — reuse is free, not lossy.
    for (f, c) in fresh_results.iter().zip(cached_results.iter()) {
        assert_eq!(f.transformation.m, c.transformation.m);
        assert_eq!(f.rmse.to_bits(), c.rmse.to_bits());
    }

    let mut t = Table::new("cached vs fresh-upload (same results, bit-identical)").header(&[
        "mode",
        "kd builds",
        "total (ms)",
        "per-scan (ms)",
        "build share",
    ]);
    let rows = [
        ("fresh upload", fresh_builds, fresh_ms),
        ("cached target", cached_builds, cached_ms),
        ("cpu align_with_tree", 1, baseline_ms),
    ];
    for (mode, builds, total) in rows {
        let share = 100.0 * (builds as f64 * build_ms) / total.max(1e-9);
        t.row(vec![
            mode.to_string(),
            builds.to_string(),
            format!("{total:.1}"),
            format!("{:.2}", total / scans as f64),
            format!("{share:.1}%"),
        ]);
    }
    t.print();

    println!(
        "\nspeedup from residency: {:.2}x  (kd builds {} -> {})",
        fresh_ms / cached_ms.max(1e-9),
        fresh_builds,
        cached_builds
    );
    assert_eq!(cached_builds, 1, "resident map must build exactly once");

    if let Ok(path) = std::env::var("FPPS_BENCH_JSON") {
        // Deterministic contract keys: run shape and kd-build counts
        // (fresh rebuilds once per scan, the resident map builds once).
        // Wall times and the speedup ratio are machine-dependent and
        // stay out of the committed baseline.
        let json = format!(
            "{{\n  \"bench\": \"target_reuse\",\n  \"scans\": {scans},\n  \
             \"map_points\": {},\n  \"fresh_builds\": {fresh_builds},\n  \
             \"cached_builds\": {cached_builds},\n  \"fresh_ms\": {fresh_ms:.1},\n  \
             \"cached_ms\": {cached_ms:.1},\n  \"speedup\": {:.2}\n}}\n",
            map.len(),
            fresh_ms / cached_ms.max(1e-9)
        );
        std::fs::write(&path, json).expect("write FPPS_BENCH_JSON");
        println!("wrote bench results to {path}");
    }
    println!("target_reuse bench complete");
}
