//! Bench: the **§V discussion** quantified — why FPPS uses a fully
//! parallel brute-force NN searcher instead of a k-d tree.
//!
//! The paper's observations, each reproduced here:
//!  1. k-d tree traversal is sequential and data-dependent → latency
//!     varies per query (bad for deterministic pipelines); per-frame
//!     delays "exceeding 250 ms in some sequences" at KITTI scale.
//!  2. Exact search needs backward tracing (backtracking), which
//!     inflates the visit count well beyond log2(M).
//!  3. The systolic brute-force array has fully deterministic latency
//!     and pipelines perfectly.
//!
//!   cargo bench --bench kdtree_vs_parallel

use fpps::hwmodel::{latency, AcceleratorConfig};
use fpps::kdtree::KdTree;
use fpps::nn;
use fpps::pointcloud::PointCloud;
use fpps::report::Table;
use fpps::rng::Pcg32;
use std::time::Instant;

fn lidar_like_cloud(n: usize, seed: u64) -> PointCloud {
    // Ring-structured like a real scan: dense near, sparse far — the
    // worst case for balanced kd-trees (highly non-uniform density).
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for _ in 0..n {
        let r = 3.0 + 80.0 * rng.uniform().powi(2);
        let a = rng.range(0.0, std::f32::consts::TAU);
        let z = rng.range(-1.7, 4.0);
        c.push([r * a.cos(), r * a.sin(), z]);
    }
    c
}

fn main() {
    // Paper scale: 4096 queries (source sample) x 130k candidates.
    let queries = lidar_like_cloud(4096, 1);
    let targets = lidar_like_cloud(131_072, 2);
    println!("workload: 4096 queries x 131072 target points (one ICP iteration's NN)\n");

    // ---- measured: kd-tree ----
    let t0 = Instant::now();
    let tree = KdTree::build(&targets);
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut per_query_ns: Vec<f64> = Vec::with_capacity(queries.len());
    let mut sum_idx = 0u64;
    for q in queries.iter() {
        let t = Instant::now();
        sum_idx += tree.nearest(q).unwrap().index as u64;
        per_query_ns.push(t.elapsed().as_nanos() as f64);
    }
    let kd_total_ms: f64 = per_query_ns.iter().sum::<f64>() / 1e6;
    per_query_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = per_query_ns[per_query_ns.len() / 2];
    let p999 = per_query_ns[(per_query_ns.len() as f64 * 0.999) as usize];

    // ---- measured: CPU brute force (1 + N threads) ----
    let t0 = Instant::now();
    for q in queries.iter().take(256) {
        sum_idx += nn::nearest_brute(&targets, q).unwrap().0 as u64;
    }
    let brute1_ms = t0.elapsed().as_secs_f64() * 1e3 * (queries.len() as f64 / 256.0);
    let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let t0 = Instant::now();
    let res = nn::nearest_brute_parallel(&targets, &queries, threads);
    let brute_par_ms = t0.elapsed().as_secs_f64() * 1e3;
    sum_idx += res[0].0 as u64;

    // ---- modelled: the FPPS systolic array ----
    let hw = AcceleratorConfig::default();
    let fpga_ms = latency::nn_search_cycles(&hw, 4096, 131_072) as f64 * hw.cycle_s() * 1e3;

    let mut t = Table::new("NN search strategies at paper scale").header(&[
        "strategy",
        "per-pass (ms)",
        "latency determinism",
        "notes",
    ]);
    t.row(vec![
        "kd-tree (PCL)".into(),
        format!("{kd_total_ms:.1}"),
        format!("p50 {p50:.0} ns, p99.9 {p999:.0} ns/query"),
        format!("+{build_ms:.1} ms build per frame"),
    ]);
    t.row(vec![
        "brute force, 1 thread".into(),
        format!("{brute1_ms:.0}"),
        "deterministic".into(),
        "extrapolated from 256 queries".into(),
    ]);
    t.row(vec![
        format!("brute force, {threads} threads"),
        format!("{brute_par_ms:.1}"),
        "deterministic".into(),
        "the intro's multi-core path".into(),
    ]);
    t.row(vec![
        format!("FPPS {}x{} systolic (model)", hw.pe_rows, hw.pe_cols),
        format!("{fpga_ms:.1}"),
        "fully deterministic".into(),
        format!("@ {} MHz, one SLR", hw.clock_mhz),
    ]);
    t.print();
    println!("(checksum {sum_idx})");

    // Paper: kd-tree per-frame delays exceed 250 ms in some sequences.
    // A frame = build + queries x iterations (~20-50 with the full
    // 120k-point source the baseline uses, not just 4096).
    let frame_ms_20 = build_ms + kd_total_ms * (120_000.0 / 4096.0) * 0.17; // ~20 iters w/ warm cache
    println!(
        "\nkd-tree per-frame estimate at full-cloud scale: >{:.0} ms \
         (paper: >250 ms in some sequences)",
        frame_ms_20
    );
    println!(
        "determinism gap: kd-tree p99.9/p50 per-query = {:.1}x — the \
         data-dependent variance §V cites;\nthe systolic array is \
         cycle-exact every pass.",
        p999 / p50
    );
    println!("kdtree_vs_parallel bench complete");
}
