//! Bench: regenerate **Table II** (FPGA resource usage summary) and the
//! Fig. 4 floorplan substitute from the analytical resource model, plus
//! a PE-array scaling ablation showing the resource/latency trade-off
//! that motivated the paper's 16×8 design point.
//!
//!   cargo bench --bench table2_resources

use fpps::hwmodel::{latency, resources, AcceleratorConfig};
use fpps::report::{pct, Table};

fn main() {
    let cfg = AcceleratorConfig::default();
    let rep = resources::report(&cfg);
    let util = resources::utilisation(&rep.total, &resources::U50);
    let paper = resources::PAPER_TABLE2;

    let mut t = Table::new("TABLE II: FPGA resource usage summary").header(&[
        "Resource",
        "Usage (model)",
        "Utilization on SLR0",
        "Overall Utilization",
        "Paper usage",
        "rel err",
    ]);
    let rows = [
        ("LUT", rep.total.lut, util[0], paper.lut),
        ("FF", rep.total.ff, util[1], paper.ff),
        ("Block RAM", rep.total.bram_36k, util[2], paper.bram_36k),
        ("DSP", rep.total.dsp, util[3], paper.dsp),
    ];
    for (name, usage, (slr, all), pv) in rows {
        let rel = (usage as f64 - pv as f64).abs() / pv as f64;
        t.row(vec![
            name.into(),
            usage.to_string(),
            pct(slr),
            pct(all),
            pv.to_string(),
            format!("{:.1}%", rel * 100.0),
        ]);
    }
    t.print();
    println!("paper SLR0 percentages: LUT 71.94 / FF 50.62 / BRAM 45.61 / DSP 80.11\n");

    let mut fp = Table::new("Floorplan breakdown (Fig. 4 substitute)").header(&[
        "Block", "LUT", "FF", "BRAM", "DSP",
    ]);
    for (name, u) in &rep.items {
        fp.row(vec![
            name.clone(),
            u.lut.to_string(),
            u.ff.to_string(),
            u.bram_36k.to_string(),
            u.dsp.to_string(),
        ]);
    }
    fp.print();

    // Ablation: PE array scaling (resources vs one-iteration latency).
    let mut ab = Table::new("\nAblation: PE array scaling (4096 x 131072 workload)").header(&[
        "PE array",
        "DSP",
        "LUT",
        "fits SLR0?",
        "NN pass (ms)",
    ]);
    for (rows_, cols) in [(4usize, 8usize), (8, 8), (8, 16), (16, 16), (16, 32)] {
        let c = AcceleratorConfig {
            pe_rows: rows_,
            pe_cols: cols,
            ..Default::default()
        };
        let r = resources::report(&c);
        let u = resources::utilisation(&r.total, &resources::U50);
        let fits = u.iter().all(|(slr, _)| *slr < 1.0);
        let ms = latency::nn_search_cycles(&c, 4096, 131_072) as f64 * c.cycle_s() * 1e3;
        ab.row(vec![
            format!("{rows_}x{cols}"),
            r.total.dsp.to_string(),
            r.total.lut.to_string(),
            if fits { "yes" } else { "NO" }.into(),
            format!("{ms:.1}"),
        ]);
    }
    ab.print();
    println!("\ntable2_resources bench complete");
}
