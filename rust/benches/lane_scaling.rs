//! Bench: multi-lane batched registration throughput — 1 lane vs K
//! lanes over the same seeded frame-pair batch.
//!
//! Each lane owns a private NativeSim backend instance, so lanes scale
//! with cores the way K accelerator queues would: aggregate throughput
//! rises while per-job latency (and bit-exact transforms — see the
//! `lane_engine` integration test) stay constant. With ≥ 4 physical
//! cores the 4-lane row shows ≥ 2× the 1-lane aggregate throughput; on
//! smaller machines the ratio tracks the core count.
//!
//!   cargo bench --bench lane_scaling
//!   FPPS_BENCH_PAIRS=64 cargo bench --bench lane_scaling   # longer run
//!   FPPS_BENCH_JSON=BENCH_lane_scaling.json cargo bench --bench lane_scaling

use fpps::coordinator::{
    run_registration_batch, sequence_pair_jobs, LaneIcpConfig, PipelineConfig,
    RegistrationJob,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::NativeSimBackend;
use fpps::report::Table;

fn batch() -> Vec<RegistrationJob> {
    let pairs: usize = std::env::var("FPPS_BENCH_PAIRS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let spec = sequence_specs()[5].clone(); // 05: urban loop
    let seq = Sequence::synthetic(
        spec,
        pairs + 1,
        2026,
        LidarConfig {
            beams: 32,
            azimuth_steps: 500,
            ..Default::default()
        },
    );
    let cfg = PipelineConfig {
        source_sample: 1024,
        target_capacity: 8192,
        ..Default::default()
    };
    sequence_pair_jobs(&seq, pairs + 1, 0, &cfg).expect("job generation")
}

fn main() {
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    let jobs = batch().len();
    println!(
        "lane scaling: {jobs} frame pairs, native-sim backend per lane, {cores} cores\n"
    );

    let mut lane_counts = vec![1usize, 2, 4];
    if cores > 4 {
        lane_counts.push(cores);
    }
    lane_counts.dedup();

    let mut t = Table::new("Aggregate throughput vs lane count").header(&[
        "lanes",
        "wall (ms)",
        "jobs/s",
        "speedup vs 1 lane",
        "p50 (ms)",
        "p99 (ms)",
        "queue wait mean (ms)",
    ]);
    let mut base_jps = 0.0f64;
    let mut four_lane_ratio = None;
    let mut measured: Vec<(usize, usize, f64)> = Vec::new();
    for &lanes in &lane_counts {
        let report = run_registration_batch(
            batch(),
            lanes,
            2 * lanes,
            LaneIcpConfig::default(),
            |_| Ok(NativeSimBackend::new()),
        )
        .expect("lane pool run");
        assert_eq!(report.outcomes.len(), jobs, "work conservation");
        let jps = report.jobs_per_s();
        measured.push((lanes, report.outcomes.len(), jps));
        if lanes == 1 {
            base_jps = jps;
        }
        let ratio = if base_jps > 0.0 { jps / base_jps } else { 0.0 };
        if lanes == 4 {
            four_lane_ratio = Some(ratio);
        }
        t.row(vec![
            lanes.to_string(),
            format!("{:.0}", report.wall_ms),
            format!("{jps:.2}"),
            format!("{ratio:.2}x"),
            format!("{:.1}", report.service.percentile_ms(50.0)),
            format!("{:.1}", report.service.percentile_ms(99.0)),
            format!("{:.1}", report.queue_wait.mean_ms()),
        ]);
        eprintln!("  {lanes} lane(s) done");
    }
    t.print();

    if let Some(r) = four_lane_ratio {
        println!(
            "\n4-lane vs 1-lane aggregate throughput: {r:.2}x \
             (target ≥ 2x with ≥ 4 cores; this host has {cores})"
        );
    }

    if let Ok(path) = std::env::var("FPPS_BENCH_JSON") {
        // Deterministic contract keys: the run shape and per-row work
        // conservation. jobs_per_s is machine-dependent and stays out
        // of the committed baseline.
        let rows: Vec<String> = measured
            .iter()
            .map(|(lanes, served, jps)| {
                format!("    {{\"lanes\": {lanes}, \"served\": {served}, \"jobs_per_s\": {jps:.2}}}")
            })
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"lane_scaling\",\n  \"jobs\": {jobs},\n  \"rows\": [\n{}\n  ]\n}}\n",
            rows.join(",\n")
        );
        std::fs::write(&path, json).expect("write FPPS_BENCH_JSON");
        println!("wrote bench results to {path}");
    }
    println!("lane_scaling bench complete");
}
