//! Bench: regenerate **Table IV** (average latency per frame and
//! acceleration rate) across the ten sequences, plus the abstract's
//! runtime-weighted average speedup (15.95× in the paper).
//!
//! * CPU rows: *measured* on this host — full raw cloud through the
//!   PCL-equivalent kd-tree ICP (the paper's Xeon Gold 6246R baseline).
//! * CPU+FPGA rows: the Alveo U50 latency model driven by the
//!   *measured* per-sequence ICP iteration counts (the accelerator is
//!   fixed-function: per-iteration time is capacity-determined, which
//!   is why the paper's own table repeats values like 537.4/136.3 ms).
//!
//! Absolute numbers shift with baseline hardware (our from-scratch rust
//! kd-tree ICP is faster per point than PCL-on-Xeon), but the *shape* —
//! accelerated wins everywhere, sequence-dependent factors, highway
//! converging slower than residential — is the reproduction target.
//!
//!   cargo bench --bench table4_latency

use fpps::bench_support::{
    bench_frames, bench_sequence, projected_fpga_ms, run_cpu_baseline, AnyBackend,
};
use fpps::dataset::sequence_specs;
use fpps::metrics::runtime_weighted_speedup;
use fpps::report::Table;

fn main() {
    let frames = bench_frames();
    let mut backend = AnyBackend::sim();
    println!(
        "Table IV reproduction: {} frames/sequence, FPPS backend = {}\n",
        frames,
        backend.name()
    );

    let paper_cpu = [3714.5, 8640.1, 1363.3, 4820.2, 2591.9, 3523.8, 5213.9, 3164.1, 3662.7, 7037.1];
    let paper_acc = [162.6, 537.4, 237.2, 136.3, 537.4, 148.7, 224.3, 145.1, 136.3, 477.6];

    let mut t = Table::new("TABLE IV: Average latency per frame and acceleration rate").header(&[
        "Sequence",
        "CPU (ms)",
        "CPU+FPGA (ms)",
        "Acceleration",
        "iters",
        "paper CPU",
        "paper CPU+FPGA",
        "paper accel",
    ]);
    let mut cpu_ms_all = Vec::new();
    let mut acc_ms_all = Vec::new();
    for (i, spec) in sequence_specs().into_iter().enumerate() {
        let seq = bench_sequence(spec, frames);
        let cpu = run_cpu_baseline(&seq, frames).expect("cpu baseline");
        let fpps = backend.run(&seq, frames).expect("fpps run");
        let fpga_ms = projected_fpga_ms(fpps.mean_iterations);
        cpu_ms_all.push(cpu.mean_latency_ms);
        acc_ms_all.push(fpga_ms);
        t.row(vec![
            seq.spec.name.to_string(),
            format!("{:.1}", cpu.mean_latency_ms),
            format!("{fpga_ms:.1}"),
            format!("{:.2}x", cpu.mean_latency_ms / fpga_ms),
            format!("{:.0}", fpps.mean_iterations),
            format!("{:.1}", paper_cpu[i]),
            format!("{:.1}", paper_acc[i]),
            format!("{:.2}x", paper_cpu[i] / paper_acc[i]),
        ]);
        eprintln!("  sequence {} done", seq.spec.name);
    }
    t.print();

    let weighted = runtime_weighted_speedup(&cpu_ms_all, &acc_ms_all);
    let max = cpu_ms_all
        .iter()
        .zip(acc_ms_all.iter())
        .map(|(c, a)| c / a)
        .fold(0.0f64, f64::max);
    println!(
        "\nruntime-weighted average speedup: {weighted:.2}x (paper: 15.95x)\n\
         max speedup: {max:.2}x (paper: 35.36x)"
    );
    println!("table4_latency bench complete");
}
