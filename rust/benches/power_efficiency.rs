//! Bench: regenerate the **§IV.D power-efficiency** analysis.
//!
//! The paper: FPGA board 28 W (14 static + 14 dynamic) + 2.3 W host vs
//! a 16.3 W CPU baseline, and an 8.58× power-efficiency gain at the
//! 15.95× runtime-weighted speedup — efficiency being energy per frame.
//! This bench (a) reproduces that arithmetic exactly, (b) recomputes
//! the gain from *this repo's* measured/modelled Table IV latencies on
//! one representative sequence, and (c) shows the dynamic-power model's
//! sensitivity to the architecture parameters.
//!
//!   cargo bench --bench power_efficiency

use fpps::bench_support::{bench_frames, bench_sequence, projected_fpga_ms, run_cpu_baseline, AnyBackend};
use fpps::dataset::sequence_specs;
use fpps::hwmodel::{power, resources, AcceleratorConfig};
use fpps::report::Table;

fn main() {
    let pm = power::PowerModel::default();

    // (a) the paper's own numbers, reproduced from the definition.
    println!("paper arithmetic check:");
    println!(
        "  accel power = {:.1} W (paper: 28 W board + 2.3 W host = 30.3 W)",
        pm.accel_total_w()
    );
    let gain_paper = pm.efficiency_gain(15.95);
    println!(
        "  efficiency gain @ paper's 15.95x speedup = {gain_paper:.2}x (paper: 8.58x)\n"
    );

    // (b) measured path: one urban + one highway sequence.
    let frames = bench_frames();
    let mut backend = AnyBackend::sim();
    let mut t = Table::new("Energy per frame (measured CPU vs modelled U50)").header(&[
        "Sequence",
        "CPU (ms)",
        "CPU energy (J)",
        "FPGA (ms)",
        "FPGA energy (J)",
        "efficiency gain",
    ]);
    for idx in [0usize, 1] {
        let spec = sequence_specs()[idx].clone();
        let seq = bench_sequence(spec, frames);
        let cpu = run_cpu_baseline(&seq, frames).expect("cpu");
        let fpps = backend.run(&seq, frames).expect("fpps");
        let fpga_ms = projected_fpga_ms(fpps.mean_iterations);
        let e_cpu = pm.cpu_energy_j(cpu.mean_latency_ms / 1e3);
        let e_fpga = pm.accel_energy_j(fpga_ms / 1e3);
        t.row(vec![
            seq.spec.name.to_string(),
            format!("{:.1}", cpu.mean_latency_ms),
            format!("{e_cpu:.2}"),
            format!("{fpga_ms:.1}"),
            format!("{e_fpga:.2}"),
            format!("{:.2}x", e_cpu / e_fpga),
        ]);
    }
    t.print();

    // (c) dynamic-power model sensitivity.
    let mut s = Table::new("\nDynamic power model vs architecture").header(&[
        "PE array",
        "clock (MHz)",
        "dynamic W (model)",
        "total W",
    ]);
    for (r, c, mhz) in [(8usize, 8usize, 300.0), (8, 16, 300.0), (8, 16, 200.0), (16, 16, 300.0)] {
        let cfg = AcceleratorConfig {
            pe_rows: r,
            pe_cols: c,
            clock_mhz: mhz,
            ..Default::default()
        };
        let usage = resources::report(&cfg).total;
        let dyn_w = power::dynamic_power_estimate(&usage, mhz);
        s.row(vec![
            format!("{r}x{c}"),
            format!("{mhz:.0}"),
            format!("{dyn_w:.1}"),
            format!("{:.1}", power::U50_STATIC_W + dyn_w + pm.host_w),
        ]);
    }
    s.print();
    println!(
        "\npaper: 14 W static + 14 W dynamic; model lands within a few watts\n\
         and scales with PE count and clock as expected."
    );
    println!("power_efficiency bench complete");
}
