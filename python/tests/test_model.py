"""Layer-2 correctness: icp_step (Pallas-backed) vs the dense oracle,
plus semantic checks of the accumulator outputs (the inputs to the
host-side Kabsch/SVD)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rigid(yaw=0.0, t=(0.0, 0.0, 0.0)):
    c, s = np.cos(yaw), np.sin(yaw)
    m = np.eye(4, dtype=np.float32)
    m[0, 0], m[0, 1], m[1, 0], m[1, 1] = c, -s, s, c
    m[:3, 3] = t
    return m


def random_inputs(n, m, seed, n_valid=None, m_valid=None):
    rng = np.random.default_rng(seed)
    src = (rng.standard_normal((n, 3)) * 5).astype(np.float32)
    tgt = (rng.standard_normal((m, 3)) * 5).astype(np.float32)
    smask = np.ones(n, np.float32)
    tmask = np.ones(m, np.float32)
    if n_valid is not None:
        smask[n_valid:] = 0.0
        src[n_valid:] = 0.0
    if m_valid is not None:
        tmask[m_valid:] = 0.0
        tgt[m_valid:] = 0.0
    return src, tgt, smask, tmask


def run_model(src, tgt, smask, tmask, T, max_d2, bn=64, bm=256):
    outs = model.icp_step(
        jnp.asarray(src), jnp.asarray(tgt), jnp.asarray(smask),
        jnp.asarray(tmask), jnp.asarray(T), jnp.float32(max_d2),
        block_n=bn, block_m=bm)
    return [np.asarray(o) for o in outs]


def run_ref(src, tgt, smask, tmask, T, max_d2):
    outs = ref.icp_step_ref(
        jnp.asarray(src), jnp.asarray(tgt), jnp.asarray(smask),
        jnp.asarray(tmask), jnp.asarray(T), jnp.float32(max_d2))
    return [np.asarray(o) for o in outs]


def assert_outputs_close(a, b, rtol=1e-5, atol=1e-3):
    names = ["count", "sum_p", "sum_q", "sum_pq", "sum_sq"]
    for name, x, y in zip(names, a, b):
        np.testing.assert_allclose(x, y, rtol=rtol, atol=atol,
                                   err_msg=f"output {name}")


class TestModelVsRef:
    def test_identity_transform(self):
        src, tgt, sm, tm = random_inputs(128, 512, seed=1)
        a = run_model(src, tgt, sm, tm, rigid(), 1e30)
        b = run_ref(src, tgt, sm, tm, rigid(), 1e30)
        assert_outputs_close(a, b)

    def test_nontrivial_transform(self):
        src, tgt, sm, tm = random_inputs(128, 512, seed=2)
        T = rigid(yaw=0.3, t=(1.0, -2.0, 0.5))
        a = run_model(src, tgt, sm, tm, T, 1e30)
        b = run_ref(src, tgt, sm, tm, T, 1e30)
        assert_outputs_close(a, b)

    def test_distance_filter(self):
        src, tgt, sm, tm = random_inputs(128, 512, seed=3)
        a = run_model(src, tgt, sm, tm, rigid(), 0.5)
        b = run_ref(src, tgt, sm, tm, rigid(), 0.5)
        assert_outputs_close(a, b)
        # And the filter actually rejects something at this density.
        full = run_model(src, tgt, sm, tm, rigid(), 1e30)
        assert a[0] < full[0]

    def test_padding_masks(self):
        src, tgt, sm, tm = random_inputs(128, 512, seed=4,
                                         n_valid=100, m_valid=400)
        a = run_model(src, tgt, sm, tm, rigid(), 1e30)
        b = run_ref(src, tgt, sm, tm, rigid(), 1e30)
        assert_outputs_close(a, b)
        # Count cannot exceed the number of valid sources.
        assert a[0] <= 100.0 + 1e-6

    def test_padding_invariance(self):
        # Adding padded rows must not change the accumulators.
        src, tgt, sm, tm = random_inputs(64, 256, seed=5)
        a = run_model(src, tgt, sm, tm, rigid(), 1e30, bn=64, bm=256)
        src2 = np.vstack([src, np.zeros((64, 3), np.float32)])
        sm2 = np.concatenate([sm, np.zeros(64, np.float32)])
        tgt2 = np.vstack([tgt, np.zeros((256, 3), np.float32)])
        tm2 = np.concatenate([tm, np.zeros(256, np.float32)])
        b = run_model(src2, tgt2, sm2, tm2, rigid(), 1e30, bn=64, bm=256)
        assert_outputs_close(a, b)

    def test_perfect_alignment_accumulators(self):
        # src == tgt, identity transform: every point matches itself at
        # distance ~0; sums are directly predictable.
        rng = np.random.default_rng(6)
        pts = (rng.standard_normal((128, 3)) * 3).astype(np.float32)
        sm = np.ones(128, np.float32)
        a = run_model(pts, pts[:512] if len(pts) >= 512 else
                      np.vstack([pts, np.zeros((512 - 128, 3), np.float32)]),
                      sm,
                      np.concatenate([sm, np.zeros(384, np.float32)]),
                      rigid(), 1e30)
        count, sum_p, sum_q, sum_pq, sum_sq = a
        assert count == pytest.approx(128.0)
        np.testing.assert_allclose(sum_p, pts.sum(axis=0), rtol=1e-4)
        np.testing.assert_allclose(sum_q, pts.sum(axis=0), rtol=1e-4)
        np.testing.assert_allclose(sum_pq, pts.T @ pts, rtol=1e-3)
        assert sum_sq == pytest.approx(0.0, abs=1e-2)

    def test_kabsch_recovers_transform_from_accumulators(self):
        # End-to-end semantic check: accumulators from a transformed
        # cloud must yield the inverse transform via Kabsch (numpy SVD
        # here; rust does Jacobi).
        rng = np.random.default_rng(7)
        tgt = (rng.standard_normal((256, 3)) * 4).astype(np.float32)
        T = rigid(yaw=0.05, t=(0.3, -0.2, 0.1))
        # src = T^-1 tgt, so transforming src by T matches tgt exactly.
        Tinv = np.linalg.inv(T)
        src = (tgt @ Tinv[:3, :3].T + Tinv[:3, 3]).astype(np.float32)
        sm = np.ones(256, np.float32)
        count, sum_p, sum_q, sum_pq, sum_sq = run_model(
            src, tgt, sm, sm, T, 1e30, bn=64, bm=256)
        n = count
        cp, cq = sum_p / n, sum_q / n
        h = sum_pq - np.outer(sum_p, sum_q) / n
        u, s, vt = np.linalg.svd(h)
        d = np.sign(np.linalg.det(vt.T @ u.T))
        r = vt.T @ np.diag([1, 1, d]) @ u.T
        # p already equals q -> R should be identity, t zero.
        np.testing.assert_allclose(r, np.eye(3), atol=1e-4)
        np.testing.assert_allclose(cq - r @ cp, 0.0, atol=1e-4)


class TestModelHypothesis:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        yaw=st.floats(-0.5, 0.5),
        tx=st.floats(-3.0, 3.0),
        max_d2=st.sampled_from([0.25, 1.0, 25.0, 1e30]),
        n_valid=st.integers(4, 128),
    )
    def test_model_matches_ref(self, seed, yaw, tx, max_d2, n_valid):
        src, tgt, sm, tm = random_inputs(128, 512, seed=seed,
                                         n_valid=n_valid)
        T = rigid(yaw=yaw, t=(tx, 0.0, 0.0))
        a = run_model(src, tgt, sm, tm, T, max_d2)
        b = run_ref(src, tgt, sm, tm, T, max_d2)
        assert_outputs_close(a, b, atol=5e-3)
