"""AOT path checks: lowering produces valid HLO text, the manifest is
well-formed, and the lowered computation (executed through jax from the
HLO-side inputs) matches the eager model — i.e. what rust will load is
numerically the same function the tests above validated."""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, model


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Only the smallest variant — keep the test fast.
    orig = aot.VARIANTS
    aot.VARIANTS = orig[:1]
    try:
        written = aot.emit(str(out))
    finally:
        aot.VARIANTS = orig
    return out, written


def test_emit_writes_hlo_and_manifest(small_artifacts):
    out, written = small_artifacts
    assert len(written) == 1
    text = open(written[0]).read()
    assert text.startswith("HloModule"), text[:80]
    # The entry computation must carry our six parameters.
    assert "f32[256,3]" in text
    assert "f32[1024,3]" in text
    assert "f32[4,4]" in text
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert "variant.icp_step_256x1024.n=256" in manifest
    assert "variant.icp_step_256x1024.file=icp_step_256x1024.hlo.txt" in manifest
    assert "variant.icp_step_256x1024.block_n=64" in manifest


def test_manifest_is_kv_parseable(small_artifacts):
    out, _ = small_artifacts
    for line in open(os.path.join(out, "manifest.txt")):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        assert "=" in line, line


def test_lowered_matches_eager():
    # Compile the lowered module and compare against eager icp_step.
    name, n, m, bn, bm = aot.VARIANTS[0]
    lowered = aot.lower_variant(n, m, bn, bm)
    compiled = lowered.compile()

    rng = np.random.default_rng(0)
    src = (rng.standard_normal((n, 3)) * 5).astype(np.float32)
    tgt = (rng.standard_normal((m, 3)) * 5).astype(np.float32)
    sm = np.ones(n, np.float32)
    tm = np.ones(m, np.float32)
    T = np.eye(4, dtype=np.float32)
    T[:3, 3] = [0.2, -0.1, 0.05]

    got = compiled(src, tgt, sm, tm, T, np.float32(1e30))
    want = model.icp_step(
        jnp.asarray(src), jnp.asarray(tgt), jnp.asarray(sm),
        jnp.asarray(tm), jnp.asarray(T), jnp.float32(1e30),
        block_n=bn, block_m=bm)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-4)


def test_hlo_text_has_expected_structure():
    # Structural sanity of the interchange text: single entry module,
    # tuple-rooted (return_tuple=True — rust unwraps with to_tuple()),
    # all six parameters present. Full parser round-trip coverage lives
    # on the rust side (runtime tests + smoke_roundtrip), which loads
    # this exact text through HloModuleProto::from_text_file.
    name, n, m, bn, bm = aot.VARIANTS[0]
    text = aot.to_hlo_text(aot.lower_variant(n, m, bn, bm))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    for i in range(6):
        assert f"parameter({i})" in text, f"missing parameter({i})"
    # Root returns the 5-element accumulator tuple.
    assert "(f32[], f32[3]" in text.replace("{", "(").replace("}", ")") \
        or "tuple(" in text


def test_full_variant_list_shapes_divisible():
    for name, n, m, bn, bm in aot.VARIANTS + aot.FULL_VARIANTS:
        assert n % bn == 0, name
        assert m % bm == 0, name
