"""Layer-1 correctness: Pallas NN kernel vs the dense jnp oracle.

This is the core correctness signal for the device kernel: exact index
agreement and distance agreement (same float form) across shapes,
block configurations, masks, and adversarial point layouts — including
hypothesis-driven randomized sweeps.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import nn_search as nnk
from compile.kernels import ref


def random_clouds(n, m, seed, scale=10.0):
    rng = np.random.default_rng(seed)
    p = (rng.standard_normal((n, 3)) * scale).astype(np.float32)
    q = (rng.standard_normal((m, 3)) * scale).astype(np.float32)
    return p, q


def run_both(p, q, qmask, block_n, block_m):
    d_k, i_k = nnk.nn_search(
        jnp.asarray(p), jnp.asarray(q), jnp.asarray(qmask),
        block_n=block_n, block_m=block_m)
    d_r, i_r = ref.nn_search_ref(
        jnp.asarray(p), jnp.asarray(q), jnp.asarray(qmask))
    return (np.asarray(d_k), np.asarray(i_k),
            np.asarray(d_r), np.asarray(i_r))


class TestKernelVsRef:
    @pytest.mark.parametrize("n,m,bn,bm", [
        (64, 256, 64, 256),      # single tile
        (128, 512, 64, 256),     # 2x2 grid
        (256, 1024, 64, 256),    # 4x4 grid
        (128, 512, 128, 512),    # default blocks, single tile
        (256, 1024, 128, 512),
    ])
    def test_indices_and_distances_match(self, n, m, bn, bm):
        p, q = random_clouds(n, m, seed=n * 31 + m)
        qmask = np.ones(m, np.float32)
        d_k, i_k, d_r, i_r = run_both(p, q, qmask, bn, bm)
        np.testing.assert_array_equal(i_k, i_r)
        np.testing.assert_allclose(d_k, d_r, rtol=1e-4, atol=1e-3)

    def test_masked_targets_never_selected(self):
        p, q = random_clouds(64, 256, seed=7)
        qmask = np.ones(256, np.float32)
        # Mask out the 128 targets closest to the first query point.
        d = np.sum((q - p[0]) ** 2, axis=1)
        qmask[np.argsort(d)[:128]] = 0.0
        d_k, i_k, d_r, i_r = run_both(p, q, qmask, 64, 256)
        np.testing.assert_array_equal(i_k, i_r)
        assert np.all(qmask[i_k] == 1.0), "kernel picked a masked target"

    def test_all_masked_gives_huge_distance(self):
        p, q = random_clouds(64, 256, seed=8)
        qmask = np.zeros(256, np.float32)
        d_k, i_k, _, _ = run_both(p, q, qmask, 64, 256)
        assert np.all(d_k >= nnk.MASKED_DIST * 0.5)

    def test_exact_duplicates_tie_break_to_lowest_index(self):
        # All targets identical: argmin must be index 0 in kernel & ref.
        p = np.zeros((64, 3), np.float32)
        q = np.ones((256, 3), np.float32)
        qmask = np.ones(256, np.float32)
        d_k, i_k, d_r, i_r = run_both(p, q, qmask, 64, 128)
        assert np.all(i_k == 0)
        np.testing.assert_array_equal(i_k, i_r)

    def test_nearest_in_last_block(self):
        # Put the true NN in the final target block to catch
        # initialisation-only bugs.
        p = np.zeros((64, 3), np.float32)
        q = np.full((512, 3), 100.0, np.float32)
        q[-1] = [0.1, 0.0, 0.0]
        qmask = np.ones(512, np.float32)
        d_k, i_k, _, _ = run_both(p, q, qmask, 64, 128)
        assert np.all(i_k == 511)
        np.testing.assert_allclose(d_k, 0.01, rtol=1e-4)

    def test_shape_validation(self):
        p, q = random_clouds(100, 512, seed=9)  # 100 % 64 != 0
        with pytest.raises(ValueError, match="not divisible"):
            nnk.nn_search(jnp.asarray(p), jnp.asarray(q),
                          jnp.ones(512), block_n=64, block_m=256)

    def test_degenerate_coincident_points(self):
        # Query exactly on a target: distance must be ~0 (identity form
        # can go slightly negative; clamp is the caller's job).
        q = np.array([[1.0, 2.0, 3.0]] + [[9.0, 9.0, 9.0]] * 255,
                     np.float32)
        p = np.tile(q[0], (64, 1))
        qmask = np.ones(256, np.float32)
        d_k, i_k, _, _ = run_both(p, q, qmask, 64, 256)
        assert np.all(i_k == 0)
        np.testing.assert_allclose(d_k, 0.0, atol=1e-4)


class TestHypothesisSweeps:
    @settings(max_examples=25, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        m_blocks=st.integers(1, 4),
        bn=st.sampled_from([32, 64]),
        bm=st.sampled_from([64, 128]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([0.1, 1.0, 100.0]),
    )
    def test_random_shapes_and_scales(self, n_blocks, m_blocks, bn, bm,
                                      seed, scale):
        n, m = n_blocks * bn, m_blocks * bm
        p, q = random_clouds(n, m, seed=seed, scale=scale)
        qmask = np.ones(m, np.float32)
        d_k, i_k, d_r, i_r = run_both(p, q, qmask, bn, bm)
        np.testing.assert_array_equal(i_k, i_r)
        np.testing.assert_allclose(d_k, d_r, rtol=1e-4,
                                   atol=1e-4 * scale * scale)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        mask_frac=st.floats(0.0, 0.9),
    )
    def test_random_masks(self, seed, mask_frac):
        rng = np.random.default_rng(seed)
        p, q = random_clouds(64, 512, seed=seed)
        qmask = (rng.random(512) >= mask_frac).astype(np.float32)
        d_k, i_k, d_r, i_r = run_both(p, q, qmask, 64, 128)
        np.testing.assert_array_equal(i_k, i_r)
        if qmask.sum() > 0:
            assert np.all(qmask[i_k] == 1.0)
