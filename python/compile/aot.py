"""AOT lowering: icp_step -> HLO text artifacts + manifest.

Run once at build time (`make artifacts`); the rust runtime loads the
HLO text via `HloModuleProto::from_text_file` and compiles it on the
PJRT CPU client.

Interchange is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest is the key=value format of rust/src/config (no JSON dep).

Usage:
    python -m compile.aot --out-dir ../artifacts
Environment:
    FPPS_FULL_ARTIFACTS=1  also emit the paper-scale 4096x131072 variant
                           (slow to lower; not needed for tests/benches).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import nn_search as nnk

# (name, N, M, block_n, block_m). N/M are buffer capacities; the rust
# runtime picks the smallest variant that fits and pads with masks.
VARIANTS = [
    ("icp_step_256x1024", 256, 1024, 64, 256),
    ("icp_step_1024x4096", 1024, 4096, 256, 1024),
    ("icp_step_4096x16384", 4096, 16384, 512, 2048),
]
FULL_VARIANTS = [
    ("icp_step_4096x131072", 4096, 131072, 512, 2048),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n, m, block_n, block_m):
    def fn(src, tgt, src_mask, tgt_mask, transform, max_dist_sq):
        return model.icp_step(src, tgt, src_mask, tgt_mask, transform,
                              max_dist_sq, block_n=block_n, block_m=block_m)

    args = (
        jax.ShapeDtypeStruct((n, 3), jnp.float32),
        jax.ShapeDtypeStruct((m, 3), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return jax.jit(fn).lower(*args)


def emit(out_dir: str, full: bool = False) -> list:
    os.makedirs(out_dir, exist_ok=True)
    variants = list(VARIANTS) + (list(FULL_VARIANTS) if full else [])
    manifest_lines = [
        "# FPPS AOT artifact manifest — written by python/compile/aot.py",
        f"kernel_default_block_n={nnk.DEFAULT_BN}",
        f"kernel_default_block_m={nnk.DEFAULT_BM}",
        f"jax_version={jax.__version__}",
    ]
    written = []
    for name, n, m, bn, bm in variants:
        lowered = lower_variant(n, m, bn, bm)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines += [
            f"variant.{name}.n={n}",
            f"variant.{name}.m={m}",
            f"variant.{name}.block_n={bn}",
            f"variant.{name}.block_m={bm}",
            f"variant.{name}.file={fname}",
        ]
        written.append(path)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(out_dir, 'manifest.txt')} "
          f"({len(variants)} variants)")
    return written


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--full", action="store_true",
                    help="also emit the paper-scale 4096x131072 variant")
    args = ap.parse_args()
    full = args.full or os.environ.get("FPPS_FULL_ARTIFACTS") == "1"
    emit(args.out_dir, full=full)


if __name__ == "__main__":
    main()
