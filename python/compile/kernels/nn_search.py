"""Layer 1 — the FPPS NN searcher (paper Fig. 3) as a Pallas kernel.

Architecture mapping (see DESIGN.md §2 "Hardware adaptation"):

* the PE array's distance tile is a (BN x BM) block computed with the
  matmul identity  ||p - q||^2 = ||p||^2 - 2 p.q + ||q||^2,  so the
  inner product lands on the MXU (the FPGA uses a DSP systolic array);
* the BlockSpec over the target cloud is the paper's BRAM partitioning +
  broadcast bus: target block j streams through while source block i is
  resident (the "local register buffer");
* the per-tile argmin is the comparison tree (CMP TR);
* the cross-tile running (min, idx) update with strict `<` is the MIN
  register pair of each PE.

The kernel must be lowered with ``interpret=True``: this CPU-only image
executes via the PJRT CPU client, which cannot run Mosaic custom calls
(see /opt/xla-example/README.md). ``interpret=True`` lowers the grid to
plain HLO (a scan over grid steps), preserving the blocked dataflow.

The rust NativeSim backend (`rust/src/nn/kernel_mirror`) re-implements
this dataflow operation-for-operation; keep the two in sync (same block
sizes, same distance form, same tie-breaking) or the backend-parity
tests will fail.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes — mirrored by rust/src/nn/mod.rs::KernelConfig.
DEFAULT_BN = 512
DEFAULT_BM = 2048

# Distance added to masked (padding) targets; mirrored by
# rust/src/nn/mod.rs::MASKED_DIST.
MASKED_DIST = 1e30


def _nn_kernel(p_ref, q_ref, qmask_ref, dist_ref, idx_ref):
    """One grid step: distance tile + tile argmin + MIN-register update."""
    j = pl.program_id(1)
    p = p_ref[...]          # (BN, 3)  resident source block
    q = q_ref[...]          # (BM, 3)  broadcast target batch
    qmask = qmask_ref[...]  # (BM,)

    # Distance tile on the MXU (matmul identity).
    pq = jnp.dot(p, q.T)                         # (BN, BM)
    pn = jnp.sum(p * p, axis=1, keepdims=True)   # (BN, 1)
    qn = jnp.sum(q * q, axis=1)[None, :]         # (1, BM)
    d = pn - 2.0 * pq + qn
    # Masked targets are pushed beyond any real distance.
    d = d + (1.0 - qmask)[None, :] * MASKED_DIST

    # Comparison tree: per-tile argmin (ties -> lowest index).
    local_min = jnp.min(d, axis=1)
    local_idx = jnp.argmin(d, axis=1).astype(jnp.int32) + j * q.shape[0]

    # MIN register pair: initialise on the first batch, then strict-<
    # update, so the global result is the first argmin — identical to a
    # serial scan over the whole target cloud.
    @pl.when(j == 0)
    def _init():
        dist_ref[...] = local_min
        idx_ref[...] = local_idx

    @pl.when(j > 0)
    def _update():
        better = local_min < dist_ref[...]
        dist_ref[...] = jnp.where(better, local_min, dist_ref[...])
        idx_ref[...] = jnp.where(better, local_idx, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def nn_search(p, q, qmask, block_n=DEFAULT_BN, block_m=DEFAULT_BM):
    """Masked exact nearest neighbour of each row of `p` in `q`.

    Args:
      p: (N, 3) f32 query points (N % block_n == 0).
      q: (M, 3) f32 target points (M % block_m == 0).
      qmask: (M,) f32 validity mask (1 = real point, 0 = padding).
      block_n / block_m: PE-array tile shape.

    Returns:
      (dist_sq, idx): (N,) f32 squared distances (identity form) and
      (N,) i32 indices of the nearest valid target.
    """
    n, m = p.shape[0], q.shape[0]
    if n % block_n or m % block_m:
        raise ValueError(f"shapes ({n},{m}) not divisible by blocks "
                         f"({block_n},{block_m})")
    grid = (n // block_n, m // block_m)
    return pl.pallas_call(
        _nn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,  # mandatory on CPU PJRT — see module docstring
    )(p, q, qmask)
