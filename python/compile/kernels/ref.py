"""Pure-jnp oracles for the Pallas kernel and the icp_step model.

These are the CORE correctness references: small, obviously-correct
dense implementations that the blocked kernel and the fused model are
tested against (python/tests/test_kernel.py, test_model.py). They also
define the exact semantics the rust NativeSim backend mirrors.
"""

import jax.numpy as jnp

MASKED_DIST = 1e30


def nn_search_ref(p, q, qmask):
    """Dense masked NN: full (N, M) distance matrix + argmin.

    Uses the same matmul-identity distance form as the kernel so the
    float rounding matches tile-for-tile.
    """
    pq = jnp.dot(p, q.T)
    pn = jnp.sum(p * p, axis=1, keepdims=True)
    qn = jnp.sum(q * q, axis=1)[None, :]
    d = pn - 2.0 * pq + qn
    d = d + (1.0 - qmask)[None, :] * MASKED_DIST
    return jnp.min(d, axis=1), jnp.argmin(d, axis=1).astype(jnp.int32)


def transform_ref(src, transform):
    """Rigid transform of (N, 3) by a 4x4 row-major matrix."""
    r = transform[:3, :3]
    t = transform[:3, 3]
    return src @ r.T + t[None, :]


def icp_step_ref(src, tgt, src_mask, tgt_mask, transform, max_dist_sq):
    """Dense reference of the full device step (transform -> NN ->
    correspondence filter -> accumulate). Returns the 5-tuple wire
    layout: count, sum_p (3,), sum_q (3,), sum_pq (3, 3), sum_sq_dist.
    """
    p = transform_ref(src, transform)
    dist, idx = nn_search_ref(p, tgt, tgt_mask)
    q = tgt[idx]
    w = src_mask * (dist <= max_dist_sq).astype(jnp.float32)
    count = jnp.sum(w)
    sum_p = jnp.sum(p * w[:, None], axis=0)
    sum_q = jnp.sum(q * w[:, None], axis=0)
    sum_pq = (p * w[:, None]).T @ q
    sum_sq = jnp.sum(dist * w)
    return count, sum_p, sum_q, sum_pq, sum_sq
