"""Layer 2 — the device-side ICP step as a single JAX computation.

This is everything the paper offloads to the FPGA kernel (Fig. 2):

  1. point cloud transformer:   p = R.src + t        (cumulative T)
  2. NN searcher:               Pallas kernel (Layer 1)
  3. correspondence filter:     w = valid & (d <= max_dist^2)
  4. result accumulator:        count, Σw.p, Σw.q, Σw.p.qᵀ, Σw.d

The host (rust Layer 3) finishes each iteration with the 3x3 SVD and the
convergence check — exactly the paper's host/kernel split. The whole
function is lowered ONCE per shape variant by aot.py; python never runs
at request time.

Output wire layout (17 f32 values; rust `StepAccumulators::from_wire`):
  [count, sum_p(3), sum_q(3), sum_pq(9, row-major), sum_sq_dist]
"""

import jax.numpy as jnp

from .kernels import nn_search as nnk


def icp_step(src, tgt, src_mask, tgt_mask, transform, max_dist_sq,
             block_n=nnk.DEFAULT_BN, block_m=nnk.DEFAULT_BM):
    """One device ICP step over fixed-capacity padded buffers.

    Args:
      src: (N, 3) f32 source cloud, padded to the variant capacity.
      tgt: (M, 3) f32 target cloud, padded.
      src_mask / tgt_mask: (N,) / (M,) f32 validity masks.
      transform: (4, 4) f32 row-major rigid transform (cumulative T).
      max_dist_sq: () f32 squared max correspondence distance.

    Returns:
      5-tuple: count, sum_p (3,), sum_q (3,), sum_pq (3, 3), sum_sq_dist.
    """
    # (1) point cloud transformer — tiny dense op, fuses into the step.
    r = transform[:3, :3]
    t = transform[:3, 3]
    p = src @ r.T + t[None, :]

    # (2) NN searcher — the Pallas kernel.
    dist, idx = nnk.nn_search(p, tgt, tgt_mask, block_n=block_n,
                              block_m=block_m)

    # (3) correspondence filter. Padding sources carry w=0; padding
    # targets were pushed to +1e30 inside the kernel, so a padded-source
    # row can never sneak in through the distance test either.
    w = src_mask * (dist <= max_dist_sq).astype(jnp.float32)

    # (4) result accumulator — the masked sums the host SVD needs.
    q = tgt[idx]
    count = jnp.sum(w)
    sum_p = jnp.sum(p * w[:, None], axis=0)
    sum_q = jnp.sum(q * w[:, None], axis=0)
    sum_pq = (p * w[:, None]).T @ q
    sum_sq = jnp.sum(dist * w)
    return count, sum_p, sum_q, sum_pq, sum_sq
